"""Real remote shard backends behind the ``RemoteShardSource`` duck type.

The prefetcher (``prefetch.py``) talks to storage through two methods:

``fetch(name) -> bytes``
    Download one whole object.  Required.

``fetch_range(name, start, length) -> bytes``
    Download ``length`` bytes starting at ``start``.  **Optional** — a
    source that provides it unlocks *index-first fetch*: the prefetcher
    pulls a shard's 32-byte header + index region first and can then fetch
    only the sample ranges a sampler window actually needs, instead of
    committing to the whole payload.  Ranges are plain absolute byte
    offsets, so columnar (format v2) projection rides the same method for
    free: a projected fetch issues ranged GETs that land inside the
    requested **column regions** only — no backend changes needed for a
    field-aware read path.

Error contract: ``FileNotFoundError`` means the object does not exist
(never retried); ``SourceUnavailable`` (an ``OSError``) means the attempt
failed in a way that may succeed on retry (5xx, dead socket, timeout).

Backends here:

``HttpShardSource``   real HTTP(S) GETs with ``Range`` header support,
                      per-thread keep-alive connection reuse, and
                      configurable timeouts.  Works against anything that
                      serves files over HTTP — object-store gateways, a
                      CDN, or the test fixture in ``testing.py``.
``RetryingSource``    wraps any source with capped exponential backoff +
                      jitter; its error/retry counters flow through
                      ``ShardPrefetcher.stats()`` into the pipeline
                      dashboard (``source_errors`` / ``source_retries``).

The peer-to-peer shard exchange (``peer.py``: ``PeerShardSource`` reading
other ranks' warm caches, ``TieredSource`` composing peers in front of the
retrying origin) sits behind the same two methods; S3/GCS-native backends
are the next target (see ROADMAP).
"""

from __future__ import annotations

import http.client
import random
import threading
import time
import urllib.parse


class SourceUnavailable(OSError):
    """A fetch failed in a way that may succeed on retry (5xx, dead socket,
    timeout).  Distinct from ``FileNotFoundError``, which is permanent.

    ``retry_after`` carries the server's ``Retry-After`` hint (seconds)
    when one was sent — admission-controlled 429s and load-shedding 503s
    use it to tell clients exactly how long to back off.  ``None`` means
    the server offered no hint and ordinary backoff applies.
    """

    def __init__(self, *args, retry_after: float | None = None):
        super().__init__(*args)
        self.retry_after = retry_after


class RangeNotSupported(Exception):
    """A ranged GET came back as a whole-object ``200`` — the server ignored
    the ``Range`` header and the ENTIRE body crossed the wire.

    Carries that body so the caller can *install* the already-downloaded
    object instead of keeping a slice and discarding the rest (which would
    force the same bytes over the wire again on the next read — the
    prefetcher turns this into a normal whole-shard cache entry, so a
    Range-ignoring origin costs exactly one wire fetch per shard).

    Deliberately not an ``OSError``: nothing failed, a retry would download
    the whole body again, so ``RetryingSource`` must let it propagate.
    """

    def __init__(self, name: str, body: bytes):
        super().__init__(
            f"{name}: server ignored Range ({len(body)}-byte whole body returned)"
        )
        self.name = name
        self.body = body


class HttpShardSource:
    """Fetches shards over HTTP(S) with connection reuse and range reads.

    One keep-alive connection per calling thread (the prefetcher's pool
    threads and demand-fetching reader threads each get their own), reused
    across fetches; a stale keep-alive socket — a server that closed an
    idle connection — is retried once on a fresh connection before the
    error escapes, since that is routine churn, not a real failure.

    ``fetch_range`` sends ``Range: bytes=a-b``.  A server that answers
    ``206 Partial Content`` gives us the true ranged read; a server that
    ignores the header and answers ``200`` moved the whole body over the
    wire — ``fetch_range`` then raises ``RangeNotSupported`` carrying that
    body (so the caller can install it instead of re-downloading) and flips
    ``range_supported`` to False so callers stop issuing ranged reads that
    do not save wire bytes.
    """

    def __init__(
        self,
        root_url: str,
        *,
        timeout: float = 30.0,
        headers: dict[str, str] | None = None,
    ):
        split = urllib.parse.urlsplit(root_url)
        if split.scheme not in ("http", "https"):
            raise ValueError(f"HttpShardSource needs an http(s) URL, got {root_url!r}")
        if not split.hostname:
            raise ValueError(f"no host in URL {root_url!r}")
        self.root_url = root_url.rstrip("/")
        self._scheme = split.scheme
        self._host = split.hostname
        self._port = split.port
        self._base_path = split.path.rstrip("/")
        self.timeout = timeout
        self.headers = dict(headers or {})
        self._local = threading.local()
        self._conns: set = set()  # every connection ever opened, for close()
        self._lock = threading.Lock()
        self.fetches = 0
        self.range_fetches = 0
        self.bytes_fetched = 0
        self.connections = 0
        #: False once a ranged request came back 200 (server ignored Range)
        self.range_supported = True

    # -- connection management ---------------------------------------------
    def _connect(self):
        cls = (
            http.client.HTTPSConnection
            if self._scheme == "https"
            else http.client.HTTPConnection
        )
        conn = cls(self._host, self._port, timeout=self.timeout)
        with self._lock:
            self._conns.add(conn)
            self.connections += 1
        return conn

    def _drop(self, conn) -> None:
        try:
            conn.close()
        except Exception:
            pass
        with self._lock:
            self._conns.discard(conn)
        if getattr(self._local, "conn", None) is conn:
            self._local.conn = None

    def _request(self, name: str, extra_headers: dict[str, str]):
        """One GET on this thread's connection; returns (response, body).

        The body is always fully read here — an HTTP/1.1 connection is only
        reusable once the previous response is drained.
        """
        path = f"{self._base_path}/{urllib.parse.quote(name)}"
        conn = getattr(self._local, "conn", None)
        fresh = conn is None
        if fresh:
            conn = self._local.conn = self._connect()
        for attempt in (0, 1):
            try:
                conn.request("GET", path, headers={**self.headers, **extra_headers})
                resp = conn.getresponse()
                body = resp.read()
                # mid-body disconnect defense: http.client raises
                # IncompleteRead itself when Content-Length is known and the
                # socket dies early, but a read-to-EOF response (no length,
                # Connection: close) or a stale header can still hand back a
                # short body.  Validate explicitly — a truncated payload
                # must surface HERE as a retryable transport error, not
                # install short and resurface later as per-sample crc holes
                # far from the cause.
                expect = resp.headers.get("Content-Length")
                if (
                    expect is not None
                    and expect.isdigit()
                    and len(body) != int(expect)
                ):
                    raise http.client.IncompleteRead(body, int(expect) - len(body))
            except (http.client.HTTPException, OSError) as e:
                self._drop(conn)
                # a dead keep-alive socket is routine: one transparent retry
                # on a fresh connection, but only if THIS request reused an
                # old one (a fresh connection failing is a real error)
                if fresh or attempt == 1:
                    raise SourceUnavailable(f"GET {path}: {e}") from e
                fresh = True
                conn = self._local.conn = self._connect()
                continue
            if resp.will_close:
                self._drop(conn)
            return resp, body
        raise AssertionError("unreachable")

    @staticmethod
    def _retry_after(resp) -> float | None:
        """Parse a numeric ``Retry-After`` on throttling responses (429 /
        503); anything unparsable is treated as absent."""
        if resp.status not in (429, 503):
            return None
        raw = resp.headers.get("Retry-After")
        if raw is None:
            return None
        try:
            return max(0.0, float(raw))
        except ValueError:
            return None

    # -- RemoteShardSource protocol ----------------------------------------
    def fetch(self, name: str) -> bytes:
        resp, body = self._request(name, {})
        if resp.status == 404:
            raise FileNotFoundError(f"{self.root_url}/{name}: 404")
        if resp.status != 200:
            raise SourceUnavailable(
                f"{self.root_url}/{name}: HTTP {resp.status} {resp.reason}",
                retry_after=self._retry_after(resp),
            )
        with self._lock:
            self.fetches += 1
            self.bytes_fetched += len(body)
        return body

    def fetch_range(self, name: str, start: int, length: int) -> bytes:
        if start < 0 or length < 0:
            raise ValueError(f"bad range start={start} length={length}")
        if length == 0:
            return b""
        resp, body = self._request(
            name, {"Range": f"bytes={start}-{start + length - 1}"}
        )
        if resp.status == 404:
            raise FileNotFoundError(f"{self.root_url}/{name}: 404")
        if resp.status == 200:
            # server ignored the Range header: the WHOLE body crossed the
            # wire.  Flip range_supported so the prefetcher stops pretending
            # ranged reads are cheap, count the true wire bytes, and hand
            # the body up — the caller installs it rather than re-fetching.
            with self._lock:
                self.range_supported = False
                self.range_fetches += 1
                self.bytes_fetched += len(body)
            raise RangeNotSupported(name, body)
        elif resp.status == 206:
            data = body
        elif resp.status == 416:
            raise ValueError(
                f"{self.root_url}/{name}: range {start}+{length} not satisfiable"
            )
        else:
            raise SourceUnavailable(
                f"{self.root_url}/{name}: HTTP {resp.status} {resp.reason}",
                retry_after=self._retry_after(resp),
            )
        with self._lock:
            self.range_fetches += 1
            self.bytes_fetched += len(body)
        if len(data) != length:
            # shorter than the index promised: the remote object is torn or
            # being overwritten — not something a retry fixes
            raise ValueError(
                f"{self.root_url}/{name}: range {start}+{length} returned "
                f"{len(data)} bytes"
            )
        return data

    # -- visibility / lifecycle --------------------------------------------
    def stats(self) -> dict[str, float]:
        with self._lock:
            return {
                "fetches": self.fetches,
                "range_fetches": self.range_fetches,
                "bytes_fetched": self.bytes_fetched,
                "connections": self.connections,
            }

    def close(self) -> None:
        with self._lock:
            conns, self._conns = self._conns, set()
        for conn in conns:
            try:
                conn.close()
            except Exception:
                pass


class RetryingSource:
    """Wraps a source with capped exponential backoff + jitter.

    Retryable errors (``SourceUnavailable``, any other ``OSError``,
    timeouts) are retried up to ``max_retries`` times with delay
    ``base_delay_s * 2**attempt`` capped at ``max_delay_s``, each scaled by
    a uniform ``[1, 1+jitter)`` factor so a fleet of loaders hammering a
    recovering server doesn't retry in lockstep.  ``FileNotFoundError`` is
    never retried — a missing object stays missing.

    Counters: ``errors`` is every failed attempt observed (including ones
    later retried into success), ``retries`` is every re-attempt made.
    Both surface in ``ShardPrefetcher.stats()`` as ``source_errors`` /
    ``source_retries`` and from there on the pipeline dashboard.

    ``fetch_range`` is exposed **iff the inner source has it**, so wrapping
    never changes what the prefetcher's protocol sniffing sees.
    ``RangeNotSupported`` is neither an error nor retryable (the body
    already arrived) — it propagates untouched.

    Two admission/deadline knobs (elastic-fleet PR):

    * ``max_elapsed_s`` — a **total** budget per logical call, attempts +
      sleeps included.  A dead origin then fails loudly in bounded time
      instead of silently burning the full retry ladder per fetch: when
      the next backoff sleep would cross the budget, the last error is
      re-raised immediately (counted in ``deadline_exhausted``).
    * a server's ``Retry-After`` hint (``SourceUnavailable.retry_after``,
      set on 429/503) **overrides** exponential backoff when it is
      longer — quota throttling waits exactly as told rather than
      hammering a server that already said when to come back.
    """

    def __init__(
        self,
        inner,
        *,
        max_retries: int = 4,
        base_delay_s: float = 0.05,
        max_delay_s: float = 2.0,
        jitter: float = 0.5,
        retry_on: tuple = (OSError, TimeoutError, http.client.HTTPException),
        no_retry: tuple = (FileNotFoundError,),
        sleep=time.sleep,
        rng: random.Random | None = None,
        max_elapsed_s: float | None = None,
        clock=time.monotonic,
    ):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if max_elapsed_s is not None and max_elapsed_s <= 0:
            raise ValueError("max_elapsed_s must be > 0 seconds")
        self.inner = inner
        self.max_retries = max_retries
        self.base_delay_s = base_delay_s
        self.max_delay_s = max_delay_s
        self.jitter = jitter
        self.retry_on = retry_on
        self.no_retry = no_retry
        self.max_elapsed_s = max_elapsed_s
        self._clock = clock
        self._sleep = sleep
        self._rng = rng or random.Random()
        self._lock = threading.Lock()
        self.errors = 0
        self.retries = 0
        self.deadline_exhausted = 0  # calls cut short by max_elapsed_s
        self.throttled = 0  # sleeps stretched by a Retry-After hint
        # expose fetch_range only when the inner source supports it, so
        # `hasattr(source, "fetch_range")` keeps answering for the wrapped
        # stack exactly what it would for the bare backend
        if callable(getattr(inner, "fetch_range", None)):
            self.fetch_range = self._fetch_range

    def _call(self, fn, args):
        delay = self.base_delay_s
        t0 = self._clock()
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args)
            except self.no_retry:
                with self._lock:
                    self.errors += 1
                raise
            except self.retry_on as e:
                with self._lock:
                    self.errors += 1
                if attempt == self.max_retries:
                    raise
                sleep_s = min(delay, self.max_delay_s) * (
                    1.0 + self.jitter * self._rng.random()
                )
                hint = getattr(e, "retry_after", None)
                if hint is not None and hint > sleep_s:
                    # the server said exactly when to come back: honor it
                    sleep_s = hint
                    with self._lock:
                        self.throttled += 1
                if (
                    self.max_elapsed_s is not None
                    and (self._clock() - t0) + sleep_s > self.max_elapsed_s
                ):
                    # the budget cannot cover another attempt: fail loudly
                    # NOW instead of sleeping past the deadline
                    with self._lock:
                        self.deadline_exhausted += 1
                    raise
                with self._lock:
                    self.retries += 1
                self._sleep(sleep_s)
                delay *= 2
        raise AssertionError("unreachable")

    def fetch(self, name: str) -> bytes:
        return self._call(self.inner.fetch, (name,))

    def _fetch_range(self, name: str, start: int, length: int) -> bytes:
        return self._call(self.inner.fetch_range, (name, start, length))

    @property
    def range_supported(self) -> bool:
        """Mirrors the inner source's view of whether ranged reads actually
        save wire bytes (True for sources that don't track it)."""
        return bool(getattr(self.inner, "range_supported", True))

    def stats(self) -> dict[str, float]:
        inner_stats = getattr(self.inner, "stats", None)
        out = dict(inner_stats()) if callable(inner_stats) else {}
        with self._lock:
            out["errors"] = self.errors
            out["retries"] = self.retries
            out["deadline_exhausted"] = self.deadline_exhausted
            out["throttled"] = self.throttled
        return out

    def close(self) -> None:
        inner_close = getattr(self.inner, "close", None)
        if callable(inner_close):
            inner_close()

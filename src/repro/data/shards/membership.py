"""Fleet membership, consistent-hash placement, and admission control.

The PR-4 peer exchange made N ranks cooperate — but over a *static* peer
list.  Production fleets churn: ranks restart, move hosts, join late.
This module supplies the three missing substrates:

* **Membership** (`MembershipRegistry` + `FleetMember`): ranks register
  with a registry (hosted by any `PeerShardServer` via its ``/fleet/*``
  endpoints) and heartbeat it.  A missed heartbeat marks the peer
  *suspect* — consumers feed that straight into the request-path circuit
  breaker instead of waiting to burn a request-time timeout.  A dead
  peer is swept from the view; a re-registered one is re-admitted live.
* **Placement** (`HashRing`): consistent hashing with virtual nodes maps
  each shard name to an owner (plus replicas).  A join/leave remaps only
  the arcs that changed hands — ~1/N of the keyspace — instead of
  reshuffling everything the way modulo placement would.
* **Admission** (`TokenBucket` / `AdmissionController`): per-tenant
  byte-rate quotas and a max-inflight cap.  Over-quota requests get a
  structured 429 + ``Retry-After`` (honored by ``RetryingSource``), so
  one greedy consumer degrades gracefully instead of collapsing the
  fleet for everyone.

Everything here is dependency-free (stdlib only) and clock-injectable
for deterministic tests.
"""

from __future__ import annotations

import bisect
import hashlib
import http.client
import json
import threading
import time
import urllib.parse
from typing import Callable, Iterable, Sequence

__all__ = [
    "AdmissionController",
    "FleetMember",
    "HashRing",
    "MembershipRegistry",
    "TENANT_HEADER",
    "TokenBucket",
]

#: Header carrying the tenant identity for admission control.
TENANT_HEADER = "X-Tenant"


def _hash64(key: str) -> int:
    """Deterministic 64-bit hash of ``key``.

    Python's builtin ``hash()`` is salted per process (PYTHONHASHSEED),
    which would remap 100% of the keyspace on every restart — the exact
    failure consistent hashing exists to avoid.  blake2b is stable,
    fast, and already in hashlib.
    """
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


class HashRing:
    """Consistent-hash ring with virtual nodes.

    Each member contributes ``vnodes`` points on a 64-bit ring; a key is
    owned by the first member point clockwise from the key's hash.
    ``owners(key, n)`` keeps walking to collect ``n`` *distinct* members
    (owner + replicas).  ``rebuild`` swaps in a new member set and
    returns how many vnode arcs changed primary owner — the bounded
    remap the tests and bench gate assert on.
    """

    def __init__(self, members: Iterable[str] = (), *, vnodes: int = 64):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        self._points: list[int] = []
        self._owners: list[str] = []
        self.members: tuple[str, ...] = ()
        self.rebuild(members)

    def _build(self, members: Sequence[str]) -> tuple[list[int], list[str]]:
        pts: list[tuple[int, str]] = []
        for m in members:
            for j in range(self.vnodes):
                pts.append((_hash64(f"{m}#{j}"), m))
        pts.sort()
        return [p for p, _ in pts], [m for _, m in pts]

    def rebuild(self, members: Iterable[str]) -> int:
        """Swap in ``members``; return the number of arc cut points whose
        primary owner changed (0 on the first build or a no-op)."""
        new_members = tuple(dict.fromkeys(members))  # dedupe, keep order
        if new_members == self.members:
            return 0
        old_points, old_owners = self._points, self._owners
        new_points, new_owners = self._build(new_members)
        moved = 0
        if old_points and new_points:
            # Sweep the union of cut points: each is the low edge of an
            # arc that is uniform in both rings, so comparing owners at
            # the cut counts exactly the arcs that changed hands.
            cuts = sorted(set(old_points) | set(new_points))
            for c in cuts:
                if self._owner_from(old_points, old_owners, c) != self._owner_from(
                    new_points, new_owners, c
                ):
                    moved += 1
        self._points, self._owners = new_points, new_owners
        self.members = new_members
        return moved

    @staticmethod
    def _owner_from(points: list[int], owners: list[str], key: int) -> str | None:
        if not points:
            return None
        i = bisect.bisect_left(points, key)
        if i == len(points):
            i = 0
        return owners[i]

    def owners(self, key: str, n: int = 1) -> list[str]:
        """Owner + up to ``n - 1`` distinct replicas for ``key``, in ring
        order.  Fewer than ``n`` if the ring has fewer members."""
        if not self._points or n < 1:
            return []
        h = _hash64(key)
        i = bisect.bisect_left(self._points, h)
        out: list[str] = []
        for k in range(len(self._points)):
            m = self._owners[(i + k) % len(self._points)]
            if m not in out:
                out.append(m)
                if len(out) == n:
                    break
        return out


class MembershipRegistry:
    """Server-side fleet view: who is live, who went quiet.

    Ranks ``register`` once and ``heartbeat`` periodically.  The registry
    is passive — liveness is evaluated lazily on access (no sweeper
    thread): a member whose last heartbeat is older than
    ``suspect_after_s`` is *suspect* (still in the view, flagged so
    consumers can bench it preemptively); older than ``dead_after_s`` it
    is removed.  ``version`` bumps on every view change so members can
    cheap-poll.
    """

    def __init__(
        self,
        *,
        suspect_after_s: float = 3.0,
        dead_after_s: float = 10.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if dead_after_s <= suspect_after_s:
            raise ValueError("dead_after_s must exceed suspect_after_s")
        self.suspect_after_s = suspect_after_s
        self.dead_after_s = dead_after_s
        self._clock = clock
        self._lock = threading.Lock()
        self._members: dict[str, dict] = {}  # id -> {url, last, suspect}
        self.version = 0
        self.joins = 0
        self.leaves = 0
        self.suspects = 0
        self.deaths = 0

    def _sweep_locked(self) -> None:
        now = self._clock()
        for pid in list(self._members):
            m = self._members[pid]
            age = now - m["last"]
            if age >= self.dead_after_s:
                del self._members[pid]
                self.deaths += 1
                self.version += 1
            elif age >= self.suspect_after_s and not m["suspect"]:
                m["suspect"] = True
                self.suspects += 1
                self.version += 1

    def register(self, peer_id: str, url: str) -> dict:
        """Admit (or re-admit) a member; returns the membership view."""
        url = url.rstrip("/")
        with self._lock:
            self._sweep_locked()
            m = self._members.get(peer_id)
            if m is None or m["url"] != url or m["suspect"]:
                self.joins += 1
                self.version += 1
            self._members[peer_id] = {
                "url": url,
                "last": self._clock(),
                "suspect": False,
            }
            return self._view_locked()

    def heartbeat(self, peer_id: str) -> bool:
        """Refresh liveness.  False means the registry no longer knows
        this member (it was swept dead) — the client must re-register."""
        with self._lock:
            self._sweep_locked()
            m = self._members.get(peer_id)
            if m is None:
                return False
            m["last"] = self._clock()
            if m["suspect"]:
                m["suspect"] = False
                self.version += 1
            return True

    def leave(self, peer_id: str) -> None:
        with self._lock:
            self._sweep_locked()
            if self._members.pop(peer_id, None) is not None:
                self.leaves += 1
                self.version += 1

    def _view_locked(self) -> dict:
        live = []
        suspect = []
        for pid, m in sorted(self._members.items()):
            entry = {"id": pid, "url": m["url"]}
            (suspect if m["suspect"] else live).append(entry)
        return {"version": self.version, "live": live, "suspect": suspect}

    def members(self) -> dict:
        """Current view: ``{"version", "live": [...], "suspect": [...]}``."""
        with self._lock:
            self._sweep_locked()
            return self._view_locked()

    def stats(self) -> dict:
        with self._lock:
            self._sweep_locked()
            n_suspect = sum(1 for m in self._members.values() if m["suspect"])
            return {
                "peers_live": len(self._members) - n_suspect,
                "peers_suspect": n_suspect,
                "version": self.version,
                "joins": self.joins,
                "leaves": self.leaves,
                "suspect_transitions": self.suspects,
                "deaths": self.deaths,
            }


def _fleet_call(registry_url: str, path: str, timeout: float) -> dict:
    """One JSON GET against a fleet registry endpoint.

    Uses ``http.client`` directly (not urllib) so env proxy settings
    can't hijack intra-fleet localhost traffic.
    """
    parts = urllib.parse.urlsplit(registry_url)
    conn = http.client.HTTPConnection(
        parts.hostname, parts.port or 80, timeout=timeout
    )
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read()
        if resp.status != 200:
            raise OSError(f"{registry_url}{path}: HTTP {resp.status}")
        return json.loads(body)
    finally:
        conn.close()


class FleetMember:
    """Client-side membership agent: registers, heartbeats, and keeps a
    ``PeerShardSource`` synced to the live ring.

    A rank that *serves* passes ``serve_url`` (it appears in other
    ranks' views); a pure consumer omits it and only mirrors the view.
    Suspect peers from the view are benched into the circuit breaker
    immediately (``mark_suspect``); a peer transitioning suspect→live is
    offered back for exactly one half-open probe (``mark_live``) rather
    than force-closed — the request path retains final say.
    """

    def __init__(
        self,
        registry_url: str,
        *,
        peer_id: str | None = None,
        serve_url: str | None = None,
        peers=None,
        heartbeat_s: float = 1.0,
        timeout: float = 2.0,
    ):
        self.registry_url = registry_url.rstrip("/")
        self.peer_id = peer_id or f"member-{_hash64(registry_url + repr(id(self))):x}"
        self.serve_url = serve_url.rstrip("/") if serve_url else None
        self.peers = peers
        self.heartbeat_s = heartbeat_s
        self.timeout = timeout
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._last_suspect: set[str] = set()
        self._seen_version = -1
        self.heartbeats = 0
        self.refreshes = 0
        self.registry_errors = 0

    # -- registry RPCs ------------------------------------------------
    def _register(self) -> dict | None:
        if self.serve_url is None:
            return _fleet_call(self.registry_url, "/fleet/members", self.timeout)
        q = urllib.parse.urlencode({"id": self.peer_id, "url": self.serve_url})
        return _fleet_call(self.registry_url, f"/fleet/register?{q}", self.timeout)

    def _heartbeat(self) -> bool:
        if self.serve_url is None:
            return True
        q = urllib.parse.urlencode({"id": self.peer_id})
        out = _fleet_call(self.registry_url, f"/fleet/heartbeat?{q}", self.timeout)
        return bool(out.get("ok"))

    def _members(self) -> dict:
        return _fleet_call(self.registry_url, "/fleet/members", self.timeout)

    # -- view application --------------------------------------------
    def _apply(self, view: dict) -> None:
        if self.peers is None:
            return
        version = view.get("version", 0)
        live = [m["url"] for m in view.get("live", ())]
        suspect = [m["url"] for m in view.get("suspect", ())]
        if self.serve_url is not None:
            live = [u for u in live if u != self.serve_url]
            suspect = [u for u in suspect if u != self.serve_url]
        if version == self._seen_version:
            return
        self._seen_version = version
        self.peers.sync_membership(live + suspect, suspect)
        now_suspect = set(suspect)
        # Only a suspect -> live *transition* earns a probe offer; an
        # always-live peer must not have its request-path cooldown reset.
        for url in self._last_suspect - now_suspect:
            if url in live:
                self.peers.mark_live(url)
        self._last_suspect = now_suspect

    def poll(self) -> None:
        """One register/heartbeat + view-refresh cycle (also the loop body)."""
        try:
            if not self._heartbeat():
                view = self._register()  # swept dead: re-join
            else:
                view = self._members()
            self.heartbeats += 1
            if view is not None:
                self.refreshes += 1
                self._apply(view)
        except (OSError, ValueError, http.client.HTTPException):
            self.registry_errors += 1

    def _run(self) -> None:
        while not self._stop.wait(self.heartbeat_s):
            self.poll()

    # -- lifecycle ----------------------------------------------------
    def start(self) -> "FleetMember":
        try:
            view = self._register()
            if view is not None:
                self.refreshes += 1
                self._apply(view)
        except (OSError, ValueError, http.client.HTTPException):
            self.registry_errors += 1
        self._thread = threading.Thread(
            target=self._run, name="fleet-member", daemon=True
        )
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self.serve_url is not None:
            try:  # best-effort goodbye; the sweep covers us if it fails
                q = urllib.parse.urlencode({"id": self.peer_id})
                _fleet_call(self.registry_url, f"/fleet/leave?{q}", self.timeout)
            except (OSError, ValueError, http.client.HTTPException):
                self.registry_errors += 1

    def __enter__(self) -> "FleetMember":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        return {
            "heartbeats": self.heartbeats,
            "refreshes": self.refreshes,
            "registry_errors": self.registry_errors,
            "seen_version": self._seen_version,
        }


class TokenBucket:
    """Byte-rate token bucket: sustained ``rate_bps`` with ``burst_bytes``
    of headroom.

    ``try_take(n)`` either admits (returns 0.0, debits — the balance may
    go negative for bodies larger than the burst, which is what enforces
    the *long-run* rate) or rejects with the seconds until ``n`` would be
    affordable, leaving tokens untouched.  The afford threshold is
    ``min(n, burst)`` so a single body larger than the whole burst can
    still eventually be admitted instead of 429ing forever.
    """

    def __init__(
        self,
        rate_bps: float,
        burst_bytes: float | None = None,
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        if rate_bps <= 0:
            raise ValueError("rate_bps must be > 0")
        self.rate_bps = float(rate_bps)
        self.burst_bytes = float(burst_bytes if burst_bytes is not None else rate_bps)
        self._clock = clock
        self._tokens = self.burst_bytes
        self._last = clock()
        self._lock = threading.Lock()

    def _refill_locked(self) -> None:
        now = self._clock()
        self._tokens = min(
            self.burst_bytes, self._tokens + (now - self._last) * self.rate_bps
        )
        self._last = now

    def try_take(self, n: int) -> float:
        """0.0 = admitted (tokens debited); > 0 = rejected, retry after
        that many seconds."""
        with self._lock:
            self._refill_locked()
            need = min(float(n), self.burst_bytes)
            if self._tokens >= need:
                self._tokens -= float(n)
                return 0.0
            return (need - self._tokens) / self.rate_bps


class AdmissionController:
    """Per-tenant token-bucket quotas plus a global max-inflight cap.

    Attach one to ``PeerShardServer`` / ``ShardHTTPServer``: the handler
    calls ``start_request()``/``end_request()`` around each request and
    ``admit(tenant, nbytes)`` before sending a body.  A non-None return
    is the ``Retry-After`` seconds for a structured 429.
    """

    def __init__(
        self,
        *,
        max_inflight: int | None = None,
        default_bps: float | None = None,
        burst_s: float = 2.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.max_inflight = max_inflight
        self.default_bps = default_bps
        self.burst_s = burst_s
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: dict[str, TokenBucket] = {}
        self._inflight = 0
        self.retry_wait_s = 0.05  # Retry-After for inflight-cap 429s
        self.quota_rejections = 0
        self.inflight_rejections = 0
        self.admitted = 0

    def set_quota(
        self, tenant: str, rate_bps: float, burst_bytes: float | None = None
    ) -> None:
        burst = burst_bytes if burst_bytes is not None else rate_bps * self.burst_s
        with self._lock:
            self._buckets[tenant] = TokenBucket(
                rate_bps, burst, clock=self._clock
            )

    def _bucket(self, tenant: str) -> TokenBucket | None:
        with self._lock:
            b = self._buckets.get(tenant)
            if b is None and self.default_bps is not None:
                b = TokenBucket(
                    self.default_bps,
                    self.default_bps * self.burst_s,
                    clock=self._clock,
                )
                self._buckets[tenant] = b
            return b

    def admit(self, tenant: str, nbytes: int) -> float | None:
        """None = admitted; float = rejected, Retry-After seconds."""
        b = self._bucket(tenant)
        if b is None:
            with self._lock:
                self.admitted += 1
            return None
        wait = b.try_take(nbytes)
        with self._lock:
            if wait > 0.0:
                self.quota_rejections += 1
            else:
                self.admitted += 1
        return None if wait == 0.0 else wait

    def start_request(self) -> bool:
        """Reserve an inflight slot; False = at capacity (429)."""
        with self._lock:
            if self.max_inflight is not None and self._inflight >= self.max_inflight:
                self.inflight_rejections += 1
                return False
            self._inflight += 1
            return True

    def end_request(self) -> None:
        with self._lock:
            self._inflight -= 1

    def stats(self) -> dict:
        with self._lock:
            return {
                "admission_rejections": self.quota_rejections
                + self.inflight_rejections,
                "quota_rejections": self.quota_rejections,
                "inflight_rejections": self.inflight_rejections,
                "admitted": self.admitted,
                "inflight": self._inflight,
                "tenants": len(self._buckets),
            }

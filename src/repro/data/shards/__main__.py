"""CLI migration tool: pack an ``ArrayDataset`` directory into shards.

Usage::

    PYTHONPATH=src python -m repro.data.shards SRC_DIR DST_DIR \
        [--samples-per-shard 1024] [--max-shard-bytes N]
"""

from __future__ import annotations

import argparse

from ..dataset import ArrayDataset
from .dataset import pack


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("src", help="ArrayDataset directory (index.txt + *.rpr)")
    parser.add_argument("dst", help="output directory for shards + manifest")
    parser.add_argument("--samples-per-shard", type=int, default=1024)
    parser.add_argument(
        "--max-shard-bytes",
        type=int,
        default=None,
        help="also roll a shard when its payload exceeds this many bytes",
    )
    args = parser.parse_args(argv)
    ds = pack(
        ArrayDataset(args.src),
        args.dst,
        samples_per_shard=args.samples_per_shard,
        max_shard_bytes=args.max_shard_bytes,
    )
    print(
        f"packed {len(ds)} samples into {ds.num_shards} shard(s) under {ds.root}"
    )


if __name__ == "__main__":
    main()

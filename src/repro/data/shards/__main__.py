"""CLI migration tool: pack a dataset directory into shards.

Usage::

    PYTHONPATH=src python -m repro.data.shards SRC_DIR DST_DIR \
        [--samples-per-shard 1024] [--max-shard-bytes N] \
        [--format-version {1,2}] [--fields image,caption]

``SRC_DIR`` is an ``ArrayDataset`` directory (index.txt + *.rpr) — or an
existing shard directory (manifest.json), which makes this the v1→v2
migration path::

    python -m repro.data.shards old_shards/ new_shards/ \
        --format-version 2 --fields image

``--format-version 2`` writes columnar shards (per-field column regions
with projection support, see ``format.py``); ``--fields`` selects which
fields survive the migration (all of them by default for columnar
sources; a one-blob source's single payload column is named by the one
``--fields`` entry, default ``data``).
"""

from __future__ import annotations

import argparse
import pathlib

from ..dataset import ArrayDataset
from .dataset import MANIFEST_NAME, ShardDataset, pack


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "src",
        help="ArrayDataset directory (index.txt + *.rpr), or a shard "
        "directory (manifest.json) to re-pack/migrate",
    )
    parser.add_argument("dst", help="output directory for shards + manifest")
    parser.add_argument("--samples-per-shard", type=int, default=1024)
    parser.add_argument(
        "--max-shard-bytes",
        type=int,
        default=None,
        help="also roll a shard when its payload exceeds this many bytes",
    )
    parser.add_argument(
        "--format-version",
        type=int,
        choices=(1, 2),
        default=1,
        help="shard layout: 1 = one blob per sample, 2 = columnar fields "
        "with projection support",
    )
    parser.add_argument(
        "--fields",
        default=None,
        help="comma-separated field names (format v2): subset to keep from "
        "a columnar source, or the column name for a one-blob source",
    )
    args = parser.parse_args(argv)
    fields = (
        tuple(f.strip() for f in args.fields.split(",") if f.strip())
        if args.fields
        else None
    )
    src_path = pathlib.Path(args.src)
    if (src_path / MANIFEST_NAME).is_file():
        source = ShardDataset(src_path)  # re-pack / migrate existing shards
    else:
        source = ArrayDataset(args.src)
    try:
        ds = pack(
            source,
            args.dst,
            samples_per_shard=args.samples_per_shard,
            max_shard_bytes=args.max_shard_bytes,
            format_version=args.format_version,
            fields=fields,
        )
    finally:
        if isinstance(source, ShardDataset):
            source.close()
    print(
        f"packed {len(ds)} samples into {ds.num_shards} shard(s) under {ds.root}"
        + (
            f" (format v2, fields: {', '.join(ds.schema_fields or ())})"
            if args.format_version == 2
            else ""
        )
    )


if __name__ == "__main__":
    main()

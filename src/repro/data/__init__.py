from .arena import ArenaClosed, SlabArena, SlotRef
from .codec import decode_sample, encode_sample
from .dataset import ArrayDataset, SyntheticImageDataset, SyntheticTokenDataset
from .loader import build_image_loader, build_lm_loader
from .sampler import CheckpointableSampler
from .shards import (
    AdmissionController,
    FleetMember,
    HashRing,
    HttpShardSource,
    LocalShardSource,
    MembershipRegistry,
    PeerShardServer,
    PeerShardSource,
    RetryingSource,
    ShardCorruption,
    ShardDataset,
    ShardPrefetcher,
    ShardReader,
    ShardWriter,
    SimulatedLatencySource,
    SourceUnavailable,
    TieredSource,
    pack,
)
from .tokenizer import ByteTokenizer

__all__ = [
    "encode_sample",
    "decode_sample",
    "ArenaClosed",
    "SlabArena",
    "SlotRef",
    "ArrayDataset",
    "SyntheticImageDataset",
    "SyntheticTokenDataset",
    "CheckpointableSampler",
    "ByteTokenizer",
    "build_image_loader",
    "build_lm_loader",
    "AdmissionController",
    "FleetMember",
    "HashRing",
    "HttpShardSource",
    "LocalShardSource",
    "MembershipRegistry",
    "PeerShardServer",
    "PeerShardSource",
    "RetryingSource",
    "ShardCorruption",
    "ShardDataset",
    "ShardPrefetcher",
    "ShardReader",
    "ShardWriter",
    "SimulatedLatencySource",
    "SourceUnavailable",
    "TieredSource",
    "pack",
]

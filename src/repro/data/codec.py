"""Array codec: the container's stand-in for JPEG/FFmpeg "media" decode.

``zstandard`` (C extension) releases the GIL during (de)compression and
numpy releases it for large array ops — exactly the property the paper's
thread-pool design exploits (§4: "functions that release the GIL entirely").
A ``py_decode`` pure-Python variant is provided as the GIL-HOLDING
counterpart for the Fig 1/2-style contention benchmarks.
"""

from __future__ import annotations

import struct

import numpy as np
import zstandard

_MAGIC = b"RPR1"
_DTYPES = {0: np.uint8, 1: np.int32, 2: np.float32, 3: np.uint16}
_DTYPE_IDS = {np.dtype(v): k for k, v in _DTYPES.items()}

# per-thread compressor/decompressor reuse (they are not thread-safe)
import threading

_tls = threading.local()


def _cctx() -> zstandard.ZstdCompressor:
    if not hasattr(_tls, "cctx"):
        _tls.cctx = zstandard.ZstdCompressor(level=1)
    return _tls.cctx


def _dctx() -> zstandard.ZstdDecompressor:
    if not hasattr(_tls, "dctx"):
        _tls.dctx = zstandard.ZstdDecompressor()
    return _tls.dctx


def encode_sample(arr: np.ndarray) -> bytes:
    """Header (magic, dtype, ndim, dims) + zstd-compressed payload."""
    arr = np.ascontiguousarray(arr)
    hdr = _MAGIC + struct.pack(
        "<BB", _DTYPE_IDS[arr.dtype], arr.ndim
    ) + struct.pack(f"<{arr.ndim}I", *arr.shape)
    return hdr + _cctx().compress(arr.tobytes())


def decode_sample(data: bytes) -> np.ndarray:
    """GIL-releasing decode (zstd C ext + numpy frombuffer)."""
    if data[:4] != _MAGIC:
        raise ValueError("bad magic: corrupt sample")
    dt_id, ndim = struct.unpack_from("<BB", data, 4)
    shape = struct.unpack_from(f"<{ndim}I", data, 6)
    off = 6 + 4 * ndim
    payload = _dctx().decompress(data[off:])
    return np.frombuffer(payload, dtype=_DTYPES[dt_id]).reshape(shape)


def py_decode(data: bytes) -> np.ndarray:
    """Pure-Python (GIL-holding) decode — the 'Pillow-like' baseline for the
    GIL-contention benchmark.  Byte-by-byte checksum walk keeps the
    interpreter busy the way PIL's Python layers do."""
    if data[:4] != _MAGIC:
        raise ValueError("bad magic")
    arr = decode_sample(data)
    acc = 0
    for bb in data[:: max(1, len(data) // 2048)]:  # interpreter-bound loop
        acc = (acc * 31 + bb) & 0xFFFFFFFF
    return arr if acc >= 0 else arr


def resize_nearest(img: np.ndarray, hw: tuple[int, int]) -> np.ndarray:
    """Nearest-neighbour resize with pure numpy (releases the GIL)."""
    h, w = hw
    ih, iw = img.shape[:2]
    yi = np.clip((np.arange(h) * ih / h).astype(np.int64), 0, ih - 1)
    xi = np.clip((np.arange(w) * iw / w).astype(np.int64), 0, iw - 1)
    return img[yi][:, xi]


def normalize_to_float(img: np.ndarray) -> np.ndarray:
    return img.astype(np.float32) / 255.0

"""Array codec: the container's stand-in for JPEG/FFmpeg "media" decode.

``zstandard`` (C extension) releases the GIL during (de)compression and
numpy releases it for large array ops — exactly the property the paper's
thread-pool design exploits (§4: "functions that release the GIL entirely").
When ``zstandard`` is not installed we fall back to stdlib ``zlib`` (also a
GIL-releasing C extension); the decoder sniffs the payload's frame magic so
either decoder reads either format.  A ``py_decode`` pure-Python variant is
provided as the GIL-HOLDING counterpart for the Fig 1/2-style contention
benchmarks.

Zero-copy variants (slab-arena path, see ``repro.data.arena``):

``decode_into(data, out)``       — decompress straight into caller-owned
                                   memory (a batch-slab row), allocating no
                                   intermediate array;
``resize_nearest_into(img, out)``— nearest-neighbour resize written into a
                                   slab row via one cached-index gather.
"""

from __future__ import annotations

import struct
import threading
import zlib

import numpy as np

try:  # optional accelerated codec; the container may not ship it
    import zstandard
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None

_MAGIC = b"RPR1"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"
_DTYPES = {0: np.uint8, 1: np.int32, 2: np.float32, 3: np.uint16}
_DTYPE_IDS = {np.dtype(v): k for k, v in _DTYPES.items()}

# per-thread compressor/decompressor reuse (they are not thread-safe)
_tls = threading.local()


def _cctx():
    if not hasattr(_tls, "cctx"):
        _tls.cctx = zstandard.ZstdCompressor(level=1)
    return _tls.cctx


def _dctx():
    if not hasattr(_tls, "dctx"):
        _tls.dctx = zstandard.ZstdDecompressor()
    return _tls.dctx


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return _cctx().compress(raw)
    return zlib.compress(raw, 1)


def _decompress(payload: bytes) -> bytes:
    if payload[:4] == _ZSTD_FRAME_MAGIC:
        if zstandard is None:
            raise ValueError("zstd-compressed sample but zstandard is not installed")
        return _dctx().decompress(payload)
    return zlib.decompress(payload)


def parse_header(data: bytes) -> tuple[np.dtype, tuple[int, ...], int]:
    """Validate the header; returns (dtype, shape, payload_offset)."""
    if data[:4] != _MAGIC:
        raise ValueError("bad magic: corrupt sample")
    dt_id, ndim = struct.unpack_from("<BB", data, 4)
    shape = struct.unpack_from(f"<{ndim}I", data, 6)
    return np.dtype(_DTYPES[dt_id]), shape, 6 + 4 * ndim


def encode_sample(arr: np.ndarray) -> bytes:
    """Header (magic, dtype, ndim, dims) + compressed payload."""
    arr = np.ascontiguousarray(arr)
    hdr = _MAGIC + struct.pack(
        "<BB", _DTYPE_IDS[arr.dtype], arr.ndim
    ) + struct.pack(f"<{arr.ndim}I", *arr.shape)
    return hdr + _compress(arr.tobytes())


def decode_sample(data: bytes) -> np.ndarray:
    """GIL-releasing decode (zstd/zlib C ext + numpy frombuffer)."""
    dtype, shape, off = parse_header(data)
    payload = _decompress(data[off:])
    return np.frombuffer(payload, dtype=dtype).reshape(shape)


def decode_into(data: bytes, out: np.ndarray) -> np.ndarray:
    """Decode directly into caller-owned memory (a slab row): zero
    intermediate arrays with zstd (``stream_reader.readinto`` writes the
    decompressed bytes straight into ``out``'s buffer), one bounce buffer
    with the zlib fallback.  ``out`` must be C-contiguous and match the
    encoded dtype/shape exactly."""
    dtype, shape, off = parse_header(data)
    if out.dtype != dtype or tuple(out.shape) != tuple(shape):
        raise ValueError(
            f"decode_into mismatch: sample is {dtype}{shape}, "
            f"out is {out.dtype}{tuple(out.shape)}"
        )
    if not out.flags["C_CONTIGUOUS"]:
        raise ValueError("decode_into requires a C-contiguous out buffer")
    payload = data[off:]
    if zstandard is not None and payload[:4] == _ZSTD_FRAME_MAGIC:
        view = memoryview(out).cast("B")
        need = out.nbytes
        got = 0
        with _dctx().stream_reader(payload) as reader:
            while got < need:
                n = reader.readinto(view[got:])
                if n == 0:
                    raise ValueError("truncated sample payload")
                got += n
            if reader.readinto(bytearray(1)):  # must be exhausted now
                raise ValueError("sample payload larger than header shape")
        return out
    raw = _decompress(payload)
    if len(raw) != out.nbytes:
        raise ValueError(
            f"sample payload is {len(raw)} bytes, header shape implies {out.nbytes}"
        )
    flat = out.reshape(-1)
    flat[:] = np.frombuffer(raw, dtype=dtype)
    return out


def py_decode(data: bytes) -> np.ndarray:
    """Pure-Python (GIL-holding) decode — the 'Pillow-like' baseline for the
    GIL-contention benchmark.  Byte-by-byte checksum walk keeps the
    interpreter busy the way PIL's Python layers do."""
    if data[:4] != _MAGIC:
        raise ValueError("bad magic")
    arr = decode_sample(data)
    acc = 0
    for bb in data[:: max(1, len(data) // 2048)]:  # interpreter-bound loop
        acc = (acc * 31 + bb) & 0xFFFFFFFF
    return arr if acc >= 0 else arr


def resize_nearest(img: np.ndarray, hw: tuple[int, int]) -> np.ndarray:
    """Nearest-neighbour resize with pure numpy (releases the GIL)."""
    h, w = hw
    ih, iw = img.shape[:2]
    yi = np.clip((np.arange(h) * ih / h).astype(np.int64), 0, ih - 1)
    xi = np.clip((np.arange(w) * iw / w).astype(np.int64), 0, iw - 1)
    return img[yi][:, xi]


# (ih, iw, h, w) -> flat gather indices; image sizes are few, so this stays
# tiny while letting resize_nearest_into run as one np.take with out=.
_RESIZE_IDX_CACHE: dict[tuple[int, int, int, int], np.ndarray] = {}
_RESIZE_IDX_LOCK = threading.Lock()


def _resize_indices(ih: int, iw: int, h: int, w: int) -> np.ndarray:
    key = (ih, iw, h, w)
    idx = _RESIZE_IDX_CACHE.get(key)
    if idx is None:
        yi = np.clip((np.arange(h) * ih / h).astype(np.int64), 0, ih - 1)
        xi = np.clip((np.arange(w) * iw / w).astype(np.int64), 0, iw - 1)
        idx = (yi[:, None] * iw + xi[None, :]).ravel()
        with _RESIZE_IDX_LOCK:
            _RESIZE_IDX_CACHE[key] = idx
    return idx


def resize_nearest_into(img: np.ndarray, out: np.ndarray) -> np.ndarray:
    """Nearest-neighbour resize written directly into ``out`` (a slab row):
    a single gather, no intermediate row/column-indexed copies."""
    h, w = out.shape[:2]
    ih, iw = img.shape[:2]
    if img.shape[2:] != out.shape[2:]:
        raise ValueError(f"channel mismatch: {img.shape} -> {out.shape}")
    if img.dtype != out.dtype:
        raise ValueError(f"dtype mismatch: {img.dtype} -> {out.dtype}")
    if not out.flags["C_CONTIGUOUS"]:  # reshape below must be a view
        raise ValueError("resize_nearest_into requires a C-contiguous out buffer")
    idx = _resize_indices(ih, iw, h, w)
    src = np.ascontiguousarray(img).reshape(ih * iw, -1)
    np.take(src, idx, axis=0, out=out.reshape(h * w, -1))
    return out


def normalize_to_float(img: np.ndarray) -> np.ndarray:
    return img.astype(np.float32) / 255.0

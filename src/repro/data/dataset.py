"""Datasets: on-disk encoded-sample stores + synthetic generators.

An ``ArrayDataset`` is a directory of ``<i>.rpr`` files (codec.py format)
plus an ``index.txt`` of relative paths — the moral equivalent of an
ImageNet directory tree.  Synthetic variants materialize deterministic
random contents so benchmarks are reproducible without real datasets.
"""

from __future__ import annotations

import pathlib

import numpy as np

from .codec import decode_sample, encode_sample


class ArrayDataset:
    """Map-style dataset over encoded array files."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        index = self.root / "index.txt"
        if not index.is_file():
            raise FileNotFoundError(
                f"not an ArrayDataset directory: {self.root} has no index.txt"
            )
        self.paths = [
            self.root / line
            for line in (ln.strip() for ln in index.read_text().splitlines())
            if line
        ]

    def __len__(self) -> int:
        return len(self.paths)

    def read_bytes(self, i: int) -> bytes:
        return self.paths[i].read_bytes()

    def __getitem__(self, i: int) -> np.ndarray:
        return decode_sample(self.read_bytes(i))


class SyntheticImageDataset(ArrayDataset):
    """Random uint8 "images" (H, W, 3), zstd-encoded on disk."""

    @staticmethod
    def materialize(
        root: str | pathlib.Path,
        n: int,
        hw: tuple[int, int] = (256, 256),
        seed: int = 0,
        corrupt_every: int = 0,
    ) -> "SyntheticImageDataset":
        root = pathlib.Path(root)
        root.mkdir(parents=True, exist_ok=True)
        rng = np.random.default_rng(seed)
        names = []
        for i in range(n):
            img = rng.integers(0, 256, (*hw, 3), dtype=np.uint8)
            data = encode_sample(img)
            if corrupt_every and i % corrupt_every == corrupt_every - 1:
                data = b"XXXX" + data[4:]  # malformed sample (robustness tests)
            name = f"{i:06d}.rpr"
            (root / name).write_bytes(data)
            names.append(name)
        (root / "index.txt").write_text("\n".join(names))
        return SyntheticImageDataset(root)


class SyntheticTokenDataset:
    """Deterministic random token documents (variable length) — in memory,
    generated per index so 'reading' has a real decode cost profile."""

    def __init__(self, n_docs: int, vocab: int, min_len: int = 64, max_len: int = 2048, seed: int = 0):
        self.n_docs = n_docs
        self.vocab = vocab
        self.min_len = min_len
        self.max_len = max_len
        self.seed = seed
        # pre-encode a small pool of compressed docs; index i -> pool entry
        rng = np.random.default_rng(seed)
        self._pool = []
        for _ in range(min(64, n_docs)):
            ln = int(rng.integers(min_len, max_len + 1))
            doc = rng.integers(0, vocab, (ln,), dtype=np.int32)
            self._pool.append(encode_sample(doc))

    def __len__(self) -> int:
        return self.n_docs

    def _pool_index(self, i: int) -> int:
        """Deterministic (seed, i) -> pool slot via a splitmix64-style mix:
        distinct indices beyond the pool size no longer alias the same bytes
        in lockstep (``i % pool``), and two datasets that differ only in
        ``seed`` disagree on which doc index ``i`` serves — keeping
        benchmark access patterns honest."""
        h = (i + 1 + self.seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return (h ^ (h >> 31)) % len(self._pool)

    def read_bytes(self, i: int) -> bytes:
        return self._pool[self._pool_index(i)]

    def __getitem__(self, i: int) -> np.ndarray:
        return decode_sample(self.read_bytes(i))

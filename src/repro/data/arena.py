"""Slab arena: preallocated, recycled batch buffers (zero-copy assembly).

The hot path of a loader allocates a fresh batch slab per ``collate`` call
and copies every decoded sample twice (decode output → collate copy →
``device_put`` staging).  FFCV-style preallocation removes both: the arena
owns a small ring of batch-shaped buffers ("slabs"); producers are handed
``(slab, slot)`` assignments *before* they decode, write their output
directly into the slot, and the slab — not a Python list of arrays — is
what flows downstream.  After the device transfer the slab is released and
recycled, so steady-state batch assembly performs **zero** allocations.

Ownership model (the contract every stage obeys):

1. ``SlabArena.acquire()`` hands out a free slab; it blocks when the ring
   is exhausted, which is the arena's backpressure: a stalled consumer can
   never force more than ``num_slabs`` slabs into existence.
2. A *binder* assigns ``SlotRef(slab, slot)`` tickets in source order.
   Once every slot of a slab is assigned the slab is *sealed*.
3. The producer that fills a slot and fails must call ``ref.mark_hole()``
   (and re-raise): holes are how the arena learns a row will never arrive.
4. The ``aggregate_into`` stage consumes refs.  A slab emitted downstream
   transfers its release authority to the consumer (``DeviceTransfer``
   calls ``slab.release()`` once the H2D copy of the *next* batch has been
   issued — double buffering).  A slab fully drained by compaction
   (every live row copied into another slab) is auto-released here.
5. ``close()`` wakes any blocked ``acquire`` with ``ArenaClosed`` so
   pipeline teardown can never hang an executor thread.

Every counter is guarded by one condition variable; refs and slabs are
plain records with no locking of their own.
"""

from __future__ import annotations

import asyncio
import threading
from collections import deque
from typing import Any, Callable, Mapping

import numpy as np

#: Key under which an emitted batch dict carries its owning slab.  The
#: terminal transfer stage pops it; user code should never see it.
SLAB_KEY = "_slab"


class ArenaClosed(RuntimeError):
    """Raised by ``acquire`` when the arena was closed (pipeline teardown)."""


class SlotRef:
    """A ticket for one row of one slab, handed out before the row exists."""

    __slots__ = ("slab", "slot")

    def __init__(self, slab: "Slab", slot: int):
        self.slab = slab
        self.slot = slot

    def views(self) -> dict[str, np.ndarray]:
        """Writable views of this row, one per arena field."""
        return {k: a[self.slot] for k, a in self.slab.arrays.items()}

    def mark_hole(self) -> None:
        """Declare that this row will never be filled (producer failed)."""
        self.slab.arena._mark_hole(self.slab)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SlotRef(slab={self.slab.index}, slot={self.slot})"


class Slab:
    """One preallocated batch buffer.  All counters are arena-guarded."""

    __slots__ = (
        "arena",
        "arrays",
        "capacity",
        "index",
        "in_use",
        "assigned",
        "sealed",
        "holes",
        "drained",
        "emitted",
    )

    def __init__(self, arena: "SlabArena", arrays: dict[str, np.ndarray], capacity: int, index: int):
        self.arena = arena
        self.arrays = arrays
        self.capacity = capacity
        self.index = index
        self.in_use = False
        self._reset()

    def _reset(self) -> None:
        self.assigned = 0
        self.sealed = False
        self.holes = 0
        self.drained = 0
        self.emitted = False

    # -- batch emission ----------------------------------------------------
    def as_batch(self, n: int | None = None) -> dict[str, Any]:
        """The slab as a batch dict (views for partial batches), tagged with
        ``SLAB_KEY`` so the transfer stage can release it."""
        if n is None or n == self.capacity:
            out: dict[str, Any] = dict(self.arrays)
        else:
            out = {k: a[:n] for k, a in self.arrays.items()}
        out[SLAB_KEY] = self
        return out

    # -- lifecycle (delegate to the arena's lock) --------------------------
    def mark_emitted(self) -> None:
        self.arena._mark_emitted(self)

    def consume_row(self) -> None:
        """One live row was copied out of (or dropped from) this slab."""
        self.arena._consume_row(self)

    def force_seal(self) -> None:
        self.arena._force_seal(self)

    def release(self) -> None:
        self.arena.release(self)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"Slab(#{self.index}, cap={self.capacity}, assigned={self.assigned},"
            f" holes={self.holes}, drained={self.drained}, emitted={self.emitted})"
        )


class SlabArena:
    """A ring of ``num_slabs`` preallocated batch buffers.

    ``spec`` maps field name → (per-item shape, dtype); every slab holds one
    ``(batch_size, *shape)`` array per field, allocated exactly once at
    construction.
    """

    def __init__(
        self,
        spec: Mapping[str, tuple[tuple[int, ...], Any]],
        *,
        batch_size: int,
        num_slabs: int = 4,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if num_slabs < 2:
            raise ValueError("num_slabs must be >= 2 (double buffering needs two)")
        self.batch_size = batch_size
        self.num_slabs = num_slabs
        self.spec = dict(spec)
        self._cond = threading.Condition()
        self._closed = False
        self._free: deque[Slab] = deque()
        self._slabs: list[Slab] = []
        for i in range(num_slabs):
            arrays = {
                k: np.empty((batch_size, *shape), dtype)
                for k, (shape, dtype) in self.spec.items()
            }
            slab = Slab(self, arrays, batch_size, i)
            self._slabs.append(slab)
            self._free.append(slab)
        self.bytes_allocated = sum(
            a.nbytes for s in self._slabs for a in s.arrays.values()
        )
        self.acquires = 0  # lifetime acquire count (reuse = acquires - num_slabs)

    # -- core ring ---------------------------------------------------------
    @property
    def slabs_in_flight(self) -> int:
        with self._cond:
            return self.num_slabs - len(self._free)

    def _take_locked(self) -> Slab:
        """Check a free slab out of the ring; caller holds the lock."""
        slab = self._free.popleft()
        slab._reset()
        slab.in_use = True
        self.acquires += 1
        return slab

    def try_acquire(self) -> Slab | None:
        """Non-blocking acquire: a slab, or None if the ring is exhausted."""
        with self._cond:
            if self._closed:
                raise ArenaClosed("arena closed")
            if not self._free:
                return None
            return self._take_locked()

    def acquire(self, timeout: float | None = None) -> Slab:
        """Take a free slab, blocking (with backpressure) until one exists.

        Raises ``ArenaClosed`` if the arena is (or becomes) closed while
        waiting, and ``TimeoutError`` on timeout.
        """
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._free or self._closed, timeout=timeout
            ):
                raise TimeoutError(f"no free slab after {timeout}s")
            if self._closed:
                raise ArenaClosed("arena closed")
            return self._take_locked()

    def release(self, slab: Slab) -> None:
        with self._cond:
            self._release_locked(slab)

    def _release_locked(self, slab: Slab) -> None:
        if not slab.in_use:
            raise RuntimeError(f"double release of {slab!r}")
        slab.in_use = False
        self._free.append(slab)
        self._cond.notify_all()

    def close(self) -> None:
        """Wake all blocked ``acquire`` calls; buffers stay valid (in-flight
        batches keep working) but no new slab can be acquired."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def seal_pending(self) -> None:
        """Force-seal every in-use, still-unsealed slab (end-of-stream).

        When the final items of a stream all fail upstream, the binder has
        assigned them slots in a slab it never finished — no ref reaches
        the aggregate stage, so nothing downstream ever seals that slab and
        its hole accounting can't recycle it.  Once EOF has propagated (the
        queues preserve order, so no ref can still be in flight) sealing
        everything pending is safe and lets ``_maybe_autorelease`` reclaim
        fully-holed slabs instead of pinning them until teardown."""
        with self._cond:
            for slab in self._slabs:
                if slab.in_use and not slab.sealed:
                    slab.sealed = True
                    self._maybe_autorelease(slab)

    # -- slab accounting (all under the one lock) --------------------------
    def _maybe_autorelease(self, slab: Slab) -> None:
        """A sealed, never-emitted slab whose rows are all holes or drained
        has no owner downstream — recycle it here."""
        if (
            slab.in_use
            and slab.sealed
            and not slab.emitted
            and slab.holes + slab.drained >= slab.assigned
        ):
            self._release_locked(slab)

    def _mark_hole(self, slab: Slab) -> None:
        with self._cond:
            slab.holes += 1
            self._maybe_autorelease(slab)

    def _consume_row(self, slab: Slab) -> None:
        with self._cond:
            slab.drained += 1
            self._maybe_autorelease(slab)

    def _force_seal(self, slab: Slab) -> None:
        with self._cond:
            slab.sealed = True
            self._maybe_autorelease(slab)

    def _mark_emitted(self, slab: Slab) -> None:
        with self._cond:
            slab.emitted = True

    # -- producer-side assignment ------------------------------------------
    def _next_ref(self, state: dict[str, Any], slab: Slab | None = None) -> SlotRef:
        """Advance the (slab, slot) cursor by one; ``slab`` is the freshly
        acquired slab when the cursor had none."""
        if slab is not None:
            state["slab"], state["slot"] = slab, 0
        slab = state["slab"]
        ref = SlotRef(slab, state["slot"])
        with self._cond:
            slab.assigned += 1
        state["slot"] += 1
        if state["slot"] >= slab.capacity:
            state["slab"] = None
            slab.force_seal()
        return ref

    def slot_writer(self) -> Callable[[], SlotRef]:
        """A stateful ``next_slot()`` that walks slots in order, acquiring a
        fresh slab whenever the current one seals.  NOT thread-safe: run it
        from a single producer (a ``concurrency=1`` stage).  Blocks in
        ``acquire`` when the ring is exhausted — call it from worker threads
        (it is meant for stage functions), never from the event loop."""
        state: dict[str, Any] = {"slab": None, "slot": 0}

        def next_slot() -> SlotRef:
            slab = self.acquire() if state["slab"] is None else None
            return self._next_ref(state, slab)

        return next_slot

    #: poll period while the ring is exhausted; only paid under backpressure
    _BINDER_STALL_POLL_S = 0.002

    def binder(self) -> Callable[[Any], Any]:
        """Async pipe-stage form of ``slot_writer``: pairs each incoming item
        with its slot ticket.  Use with ``concurrency=1`` (assignment must
        follow input order).  Ticket issue runs on the event loop (cheap
        bookkeeping, no executor round-trip per item); when the ring is
        exhausted it polls with a short async sleep — the arena's
        backpressure propagating upstream without stalling the loop or
        borrowing threads the pipeline doesn't own."""
        state: dict[str, Any] = {"slab": None, "slot": 0}

        async def bind(item: Any) -> tuple[Any, SlotRef]:
            slab = None
            if state["slab"] is None:
                while (slab := self.try_acquire()) is None:
                    await asyncio.sleep(self._BINDER_STALL_POLL_S)
            return item, self._next_ref(state, slab)

        return bind

    def stats(self) -> dict[str, int]:
        with self._cond:
            return {
                "bytes_allocated": self.bytes_allocated,
                "slabs_in_flight": self.num_slabs - len(self._free),
                "num_slabs": self.num_slabs,
                "acquires": self.acquires,
            }

"""Checkpointable, shard-aware sampler.

Solves the paper's §3 multi-processing critique head-on: with thread-based
loading the sampler state lives in ONE place, so "which samples have been
consumed" is exactly checkpointable — ``state_dict()`` is saved with the
model checkpoint and training resumes with no overlap and no gaps.

Deterministic shuffling: per-epoch permutation from (seed, epoch); each data
rank takes a strided slice (rank::world) of the permutation.

Shard-aware mode (``shard_sizes=...``): a uniform global shuffle visits
shards in random order per *sample*, which defeats any shard-granular cache
— every batch touches dozens of shards.  Instead the epoch order is built
as (1) shuffle the *shards*, (2) concatenate their sample ranges, (3) a
bounded-displacement local shuffle: no sample moves more than
``shard_window`` positions from its place in the shard-ordered stream.
Randomness stays good enough for SGD while any run of W consecutive
samples draws from at most ~(W + shard_window) consecutive positions of
the shard-ordered stream — i.e. a handful of shards — so the prefetcher's
local cache actually hits.  The
order is still a pure function of (seed, epoch, shard_sizes, shard_window),
so ``state_dict``/``load_state_dict`` resume stays exactly checkpointable.

Multi-rank caveat: each rank takes its strided ``rank::world`` slice AFTER
the window shuffle (that is what keeps the cross-rank partition exact), so
a run of W per-rank samples spans ~``W * world`` stream positions — the
per-rank locality window is effectively ``shard_window / world``.  Large
``world`` deployments should scale ``shard_window`` (and/or the cache byte
budget) by ``world`` to keep per-rank cache hit rates.
"""

from __future__ import annotations

import threading
from typing import Iterator, Sequence

import numpy as np


def _window_shuffle(idx: np.ndarray, window: int, rng: np.random.Generator) -> np.ndarray:
    """Bounded-displacement local shuffle: two vectorized passes of
    within-block permutations (block ``b = window // 2``; the second pass
    offset by ``b // 2`` so elements cross block boundaries).  Every element
    ends within ``window`` positions of where it started — the property the
    shard cache relies on — while staying O(n) *vectorized* (a streaming
    shuffle-buffer has the same guarantee but is an inherently sequential
    Python loop: seconds per epoch at the million-sample scale shards are
    for).  Deterministic given ``rng``."""
    n = len(idx)
    if window <= 1 or n <= 2:
        return idx
    b = max(2, min(window, n) // 2)
    out = idx.copy()
    for offset in (0, b // 2):
        core = out[offset:]
        m = len(core) - (len(core) % b)
        if m:
            core[:m] = rng.permuted(core[:m].reshape(-1, b), axis=1).reshape(-1)
        if len(core) > m:
            core[m:] = rng.permutation(core[m:])
    return out


class CheckpointableSampler:
    def __init__(
        self,
        n: int,
        *,
        batch_size: int,
        seed: int = 0,
        rank: int = 0,
        world: int = 1,
        shuffle: bool = True,
        drop_last: bool = True,
        shard_sizes: Sequence[int] | None = None,
        shard_window: int = 2048,
    ):
        assert 0 <= rank < world
        if shard_sizes is not None:
            shard_sizes = [int(s) for s in shard_sizes]
            if sum(shard_sizes) != n:
                raise ValueError(
                    f"shard_sizes sum to {sum(shard_sizes)}, dataset has {n} samples"
                )
            if shard_window < 1:
                raise ValueError("shard_window must be >= 1")
        self.n = n
        self.batch_size = batch_size
        self.seed = seed
        self.rank = rank
        self.world = world
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.shard_sizes = shard_sizes
        self.shard_window = shard_window
        self.epoch = 0
        self.cursor = 0  # batches yielded within the current epoch (this rank)
        self._lock = threading.Lock()

    # -- iteration -----------------------------------------------------------
    def _epoch_order(self, epoch: int) -> np.ndarray:
        if self.shard_sizes and self.shuffle:
            rng = np.random.default_rng((self.seed, epoch))
            starts = np.concatenate(([0], np.cumsum(self.shard_sizes)))
            shard_order = rng.permutation(len(self.shard_sizes))
            idx = np.concatenate(
                [
                    np.arange(starts[s], starts[s + 1], dtype=np.int64)
                    for s in shard_order
                ]
            )
            idx = _window_shuffle(idx, self.shard_window, rng)
            return idx[self.rank :: self.world]
        idx = np.arange(self.n, dtype=np.int64)
        if self.shuffle:
            rng = np.random.default_rng((self.seed, epoch))
            rng.shuffle(idx)
        return idx[self.rank :: self.world]

    def batches_per_epoch(self) -> int:
        local = (self.n + self.world - 1 - self.rank) // self.world
        if self.drop_last:
            return local // self.batch_size
        return -(-local // self.batch_size)

    def __iter__(self) -> Iterator[list[int]]:
        """Infinite stream of index batches, resuming from (epoch, cursor)."""
        while True:
            with self._lock:
                epoch, start = self.epoch, self.cursor
            order = self._epoch_order(epoch)
            nb = self.batches_per_epoch()
            for bi in range(start, nb):
                batch = order[bi * self.batch_size : (bi + 1) * self.batch_size]
                # advance BEFORE yielding: the cursor means "batches handed
                # out"; a checkpoint taken mid-prefetch skips at most the
                # sink-buffered batches (bounded, documented in DESIGN §7)
                with self._lock:
                    self.cursor = bi + 1
                yield batch.tolist()
            with self._lock:
                self.epoch = epoch + 1
                self.cursor = 0

    # -- checkpointing --------------------------------------------------------
    def state_dict(self) -> dict:
        with self._lock:
            return {
                "epoch": self.epoch,
                "cursor": self.cursor,
                "seed": self.seed,
                "rank": self.rank,
                "world": self.world,
                "n": self.n,
                "batch_size": self.batch_size,
                "shard_sizes": self.shard_sizes,
                "shard_window": self.shard_window,
            }

    def load_state_dict(self, state: dict) -> None:
        assert state["n"] == self.n and state["batch_size"] == self.batch_size, (
            "sampler checkpoint does not match dataset/batch configuration"
        )
        # The epoch order is a pure function of (seed, epoch, shard_sizes,
        # shard_window): a MID-EPOCH cursor only means anything under the
        # order it was counted in, so resuming it under a different layout
        # (repacked dataset, changed window, or a pre-shard checkpoint with
        # no shard keys at all) would silently repeat some samples and skip
        # others — fail loudly instead.  A cursor of 0 consumed nothing of
        # the epoch, so any layout may resume there.
        saved_sizes = state.get("shard_sizes")  # None for pre-shard ckpts too
        layout_matches = saved_sizes == self.shard_sizes and (
            saved_sizes is None or state.get("shard_window") == self.shard_window
        )
        assert layout_matches or state["cursor"] == 0, (
            "sampler checkpoint was taken mid-epoch under a different shard "
            f"configuration (saved shard_sizes/window {saved_sizes}/"
            f"{state.get('shard_window')}, sampler has "
            f"{self.shard_sizes}/{self.shard_window}) — repacking the "
            "dataset or changing shard_window invalidates mid-epoch resume"
        )
        with self._lock:
            self.epoch = state["epoch"]
            self.cursor = state["cursor"]
            self.seed = state["seed"]

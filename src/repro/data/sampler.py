"""Checkpointable, shard-aware sampler.

Solves the paper's §3 multi-processing critique head-on: with thread-based
loading the sampler state lives in ONE place, so "which samples have been
consumed" is exactly checkpointable — ``state_dict()`` is saved with the
model checkpoint and training resumes with no overlap and no gaps.

Deterministic shuffling: per-epoch permutation from (seed, epoch); each data
rank takes a strided slice (rank::world) of the permutation.
"""

from __future__ import annotations

import threading
from typing import Iterator

import numpy as np


class CheckpointableSampler:
    def __init__(
        self,
        n: int,
        *,
        batch_size: int,
        seed: int = 0,
        rank: int = 0,
        world: int = 1,
        shuffle: bool = True,
        drop_last: bool = True,
    ):
        assert 0 <= rank < world
        self.n = n
        self.batch_size = batch_size
        self.seed = seed
        self.rank = rank
        self.world = world
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.epoch = 0
        self.cursor = 0  # batches yielded within the current epoch (this rank)
        self._lock = threading.Lock()

    # -- iteration -----------------------------------------------------------
    def _epoch_order(self, epoch: int) -> np.ndarray:
        idx = np.arange(self.n, dtype=np.int64)
        if self.shuffle:
            rng = np.random.default_rng((self.seed, epoch))
            rng.shuffle(idx)
        return idx[self.rank :: self.world]

    def batches_per_epoch(self) -> int:
        local = (self.n + self.world - 1 - self.rank) // self.world
        if self.drop_last:
            return local // self.batch_size
        return -(-local // self.batch_size)

    def __iter__(self) -> Iterator[list[int]]:
        """Infinite stream of index batches, resuming from (epoch, cursor)."""
        while True:
            with self._lock:
                epoch, start = self.epoch, self.cursor
            order = self._epoch_order(epoch)
            nb = self.batches_per_epoch()
            for bi in range(start, nb):
                batch = order[bi * self.batch_size : (bi + 1) * self.batch_size]
                # advance BEFORE yielding: the cursor means "batches handed
                # out"; a checkpoint taken mid-prefetch skips at most the
                # sink-buffered batches (bounded, documented in DESIGN §7)
                with self._lock:
                    self.cursor = bi + 1
                yield batch.tolist()
            with self._lock:
                self.epoch = epoch + 1
                self.cursor = 0

    # -- checkpointing --------------------------------------------------------
    def state_dict(self) -> dict:
        with self._lock:
            return {
                "epoch": self.epoch,
                "cursor": self.cursor,
                "seed": self.seed,
                "rank": self.rank,
                "world": self.world,
                "n": self.n,
                "batch_size": self.batch_size,
            }

    def load_state_dict(self, state: dict) -> None:
        assert state["n"] == self.n and state["batch_size"] == self.batch_size, (
            "sampler checkpoint does not match dataset/batch configuration"
        )
        with self._lock:
            self.epoch = state["epoch"]
            self.cursor = state["cursor"]
            self.seed = state["seed"]

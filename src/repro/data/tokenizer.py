"""Byte-level tokenizer (vectorized numpy, releases the GIL on bulk ops)."""

from __future__ import annotations

import numpy as np


class ByteTokenizer:
    """ids = byte + n_special; specials: 0=pad, 1=bos, 2=eos."""

    PAD, BOS, EOS = 0, 1, 2
    N_SPECIAL = 3

    def __init__(self, vocab_size: int | None = None):
        self.vocab_size = vocab_size or (256 + self.N_SPECIAL)

    def encode(self, text: str | bytes, *, add_bos: bool = True, add_eos: bool = True) -> np.ndarray:
        raw = text.encode() if isinstance(text, str) else text
        body = np.frombuffer(raw, dtype=np.uint8).astype(np.int32) + self.N_SPECIAL
        parts = []
        if add_bos:
            parts.append(np.array([self.BOS], np.int32))
        parts.append(body)
        if add_eos:
            parts.append(np.array([self.EOS], np.int32))
        out = np.concatenate(parts)
        return np.minimum(out, self.vocab_size - 1)

    def decode(self, ids: np.ndarray) -> bytes:
        ids = np.asarray(ids)
        body = ids[(ids >= self.N_SPECIAL)] - self.N_SPECIAL
        return body.astype(np.uint8).tobytes()

"""Baseline loaders the paper benchmarks against, reimplemented faithfully.

``MPLoader`` — process-based loading à la PyTorch DataLoader: N worker
processes, EACH receiving a pickled copy of the dataset object (the paper's
Table 2 startup cost and Fig 7 memory duplication), batches pickled back
over pipes, one-at-a-time deserialization in the parent (§3 "sequential
serialization").

``DecordLikeLoader`` — §5.3.4 critique: eagerly opens and decodes headers of
EVERY file at init (init time grows with dataset size, Table 4), keeps all
decoder state resident (unbounded resources), and dies on the first
malformed sample instead of skipping it.
"""

from __future__ import annotations

import multiprocessing as mp
import time
from typing import Iterator

import numpy as np

from .codec import decode_sample, resize_nearest


def _mp_worker(dataset, hw, in_q: "mp.Queue", out_q: "mp.Queue") -> None:
    # `dataset` arrived pickled — the per-worker copy the paper measures
    while True:
        task = in_q.get()
        if task is None:
            break
        bi, indices = task
        imgs = [resize_nearest(decode_sample(dataset.read_bytes(i)), hw) for i in indices]
        batch = np.stack(imgs)
        out_q.put((bi, batch))  # pickled through the pipe (IPC cost)


class MPLoader:
    """Process-pool image loader (the PyTorch-DataLoader-shaped baseline)."""

    def __init__(self, dataset, *, batch_size=32, hw=(224, 224), num_workers=2, prefetch=2):
        self.dataset = dataset
        self.batch_size = batch_size
        self.hw = hw
        self.num_workers = num_workers
        self.prefetch = prefetch
        self.startup_s = 0.0

    def __iter__(self) -> Iterator[np.ndarray]:
        t0 = time.monotonic()
        ctx = mp.get_context("spawn")  # worker startup cost is part of the story
        in_q: mp.Queue = ctx.Queue()
        out_q: mp.Queue = ctx.Queue(self.prefetch * self.num_workers)
        procs = [
            ctx.Process(
                target=_mp_worker, args=(self.dataset, self.hw, in_q, out_q), daemon=True
            )
            for _ in range(self.num_workers)
        ]
        for p in procs:
            p.start()
        n_batches = len(self.dataset) // self.batch_size
        for bi in range(n_batches):
            idx = list(range(bi * self.batch_size, (bi + 1) * self.batch_size))
            in_q.put((bi, idx))
        self.startup_s = time.monotonic() - t0
        try:
            pending: dict[int, np.ndarray] = {}
            next_bi = 0
            received = 0
            while received < n_batches:
                bi, batch = out_q.get()  # parent deserializes one-by-one (§3)
                pending[bi] = batch
                received += 1
                while next_bi in pending:
                    yield pending.pop(next_bi)
                    next_bi += 1
        finally:
            for _ in procs:
                in_q.put(None)
            for p in procs:
                p.join(timeout=5)
                if p.is_alive():
                    p.terminate()


class DecordLikeLoader:
    """Eager-init loader with unbounded resource usage (§5.3.4)."""

    def __init__(self, dataset, *, batch_size=8, hw=(224, 224)):
        self.batch_size = batch_size
        self.hw = hw
        t0 = time.monotonic()
        # open + decode EVERYTHING up front; fail hard on any bad sample
        self._decoded = [decode_sample(dataset.read_bytes(i)) for i in range(len(dataset))]
        self.init_s = time.monotonic() - t0

    def __iter__(self) -> Iterator[np.ndarray]:
        for bi in range(len(self._decoded) // self.batch_size):
            imgs = [
                resize_nearest(img, self.hw)
                for img in self._decoded[bi * self.batch_size : (bi + 1) * self.batch_size]
            ]
            yield np.stack(imgs)

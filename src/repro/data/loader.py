"""High-level loaders: SPDL pipelines wired for the two workload families.

``build_image_loader``  — the paper's benchmark pipeline: sample indices →
read bytes (I/O) → decode+resize (GIL-releasing CPU) → collate into one
contiguous batch → device transfer (concurrency=1).

``build_lm_loader``     — the LM-training pipeline used by the trainer:
index batches → read docs → decode → tokenize/pack into (seq_len,) rows
with segment ids → collate → shard-aware device placement.

Every stage's concurrency is tunable (paper "Tunability"); stats from
``Pipeline.stats()`` expose the bottleneck stage (paper "Visibility").
"""

from __future__ import annotations

from typing import Any

import numpy as np

from ..core import Pipeline, PipelineBuilder
from .codec import decode_sample, resize_nearest
from .packing import SequencePacker, collate
from .sampler import CheckpointableSampler
from .transfer import DeviceTransfer


def build_image_loader(
    dataset,
    *,
    batch_size: int = 32,
    hw: tuple[int, int] = (224, 224),
    read_concurrency: int = 4,
    decode_concurrency: int = 4,
    num_threads: int = 8,
    sink_buffer: int = 3,
    shardings: Any | None = None,
    uint8_wire: bool = True,
    sampler: CheckpointableSampler | None = None,
    epochs: int | None = 1,  # None = stream forever (training);  N = bounded
) -> Pipeline:
    sampler = sampler or CheckpointableSampler(len(dataset), batch_size=1, shuffle=False)

    def indices():
        limit = None if epochs is None else sampler.batches_per_epoch() * epochs
        for k, batch in enumerate(sampler):
            if limit is not None and k >= limit:
                return
            yield from batch

    def read(i: int) -> bytes:
        return dataset.read_bytes(i)

    def decode(data: bytes) -> np.ndarray:
        img = decode_sample(data)
        return resize_nearest(img, hw)

    def make_batch(imgs: list[np.ndarray]) -> dict:
        out = np.empty((len(imgs), *imgs[0].shape), imgs[0].dtype)
        for j, im in enumerate(imgs):
            out[j] = im
        return {"images": out}

    transfer = DeviceTransfer(shardings, uint8_wire=uint8_wire)
    return (
        PipelineBuilder()
        .add_source(indices(), name="sampler")
        .pipe(read, concurrency=read_concurrency, name="read")
        .pipe(decode, concurrency=decode_concurrency, name="decode")
        .aggregate(batch_size, drop_last=True, name="batch")
        .pipe(make_batch, name="collate")
        .pipe(transfer, concurrency=1, name="transfer")  # §2.1: exactly one
        .add_sink(buffer_size=sink_buffer)
        .build(num_threads=num_threads)
    )


def build_lm_loader(
    dataset,
    *,
    seq_len: int,
    batch_size: int,
    sampler: CheckpointableSampler | None = None,
    read_concurrency: int = 4,
    decode_concurrency: int = 4,
    num_threads: int = 8,
    sink_buffer: int = 2,
    shardings: Any | None = None,
    seed: int = 0,
) -> tuple[Pipeline, CheckpointableSampler]:
    """Returns (pipeline, sampler) — the sampler is checkpointed alongside
    model state (fault tolerance; see runtime/trainer.py)."""
    sampler = sampler or CheckpointableSampler(
        len(dataset), batch_size=8, seed=seed, shuffle=True
    )
    packer = SequencePacker(seq_len)

    def doc_ids():
        for batch in sampler:
            yield from batch

    def read(i: int) -> bytes:
        return dataset.read_bytes(i)

    def pack(data: bytes) -> list[dict]:
        doc = decode_sample(data)
        return packer.add(doc)  # 0..k completed rows

    transfer = DeviceTransfer(shardings)
    pipe = (
        PipelineBuilder()
        .add_source(doc_ids(), name="sampler")
        .pipe(read, concurrency=read_concurrency, name="read")
        .pipe(pack, concurrency=1, name="decode+pack")  # packer is stateful
        .disaggregate(name="rows")
        .aggregate(batch_size, drop_last=True, name="batch")
        .pipe(collate, concurrency=decode_concurrency, name="collate")
        .pipe(transfer, concurrency=1, name="transfer")
        .add_sink(buffer_size=sink_buffer)
        .build(num_threads=num_threads)
    )
    return pipe, sampler

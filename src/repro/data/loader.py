"""High-level loaders: SPDL pipelines wired for the two workload families.

``build_image_loader``  — the paper's benchmark pipeline: sample indices →
slot assignment → read bytes (I/O) → decode+resize (GIL-releasing CPU,
written in place) → slab batch assembly → device transfer (concurrency=1).

``build_lm_loader``     — the LM-training pipeline used by the trainer:
index batches → read docs → decode+tokenize/pack into (seq_len,) slab rows
with segment ids → slab batch assembly → shard-aware device placement.

Every stage's concurrency is tunable (paper "Tunability"); stats from
``Pipeline.stats()`` expose the bottleneck stage (paper "Visibility") and,
for the slab path, memory pressure (``slabs_in_flight``/``bytes_allocated``).

Memory model (zero-copy slab path, default ``zero_copy=True``)
---------------------------------------------------------------
Batches are assembled in a ``SlabArena``: a ring of ``arena_slabs``
preallocated ``(batch, *item_shape)`` buffers that the pipeline recycles
instead of reallocating.  Ownership rules:

1. **Producers do not own their outputs.**  A ``concurrency=1`` binder stage
   pairs every sample with a ``(slab, slot)`` ticket *before* decode; decode
   workers write their result directly into the assigned slot (GIL-released,
   concurrent — distinct slots never alias).
2. **Acquisition is the backpressure.**  ``arena.acquire()`` blocks (in the
   worker pool, never on the event loop) while all slabs are in flight, so a
   stalled consumer bounds host memory at ``arena_slabs`` slabs — the arena
   can never exceed its ring size.
3. **A failed sample leaves a hole.**  The decode wrapper calls
   ``ref.mark_hole()`` and re-raises (so stage stats still count the
   failure); the ``aggregate_into`` stage compacts around holes by copying
   only displaced rows, keeping emitted batches dense.
4. **Release follows the device copy.**  An emitted slab travels to
   ``DeviceTransfer``, which double-buffers: slab *k* returns to the arena
   only after the transfer for slab *k+1* has been issued — or, on
   zero-copy backends where ``device_put`` aliases host memory (CPU), only
   after the whole consumer window (``sink_buffer`` + the batch in hand)
   has moved past it; the ring is sized automatically for either case.
   Consumers that retain batches beyond the current iteration must copy
   them.  Slabs fully drained by compaction (never emitted) are recycled
   by the arena itself.
5. **Teardown can't hang.**  ``Pipeline.stop()`` first runs
   ``arena.close()`` (registered as a stop callback), waking any worker
   blocked in ``acquire`` with ``ArenaClosed``.

``zero_copy=False`` restores the classic list-collate path (one fresh slab
allocation + one extra copy per sample per batch) — the fallback for ragged
shapes or third-party stages that retain references into batches.

Chunked + fused execution (``chunk=``, default 16)
--------------------------------------------------
With the storage path this fast, the engine's per-item event-loop cost
(queue hops, task creation, executor dispatch — ~4-5 round trips per stage
per sample) is the remaining ceiling, so both loaders run their per-sample
stages chunked and fused:

* the slot binder and the read/decode stages take ``chunk=N``: one executor
  call per N samples instead of per sample (``pipe(..., chunk=N)``);
* read → decode are **fused** into a single worker call per chunk
  (``builder.fuse("read", "decode")``), eliminating the queue + task layer
  between them — ``Pipeline.stats()`` still shows them as separate rows.

Ordering and memory rules under chunking:

* Order is preserved end to end: chunks are dispatched and emitted in FIFO
  order and items keep their order within a chunk, so the
  ``aggregate_into`` input-order contract holds unchanged.
* Slab slot assignment makes chunked decode-into safe: every item carries
  its own ``(slab, slot)`` ticket, so the N decodes of a chunk write to
  disjoint rows no matter how chunks interleave across worker threads.
* A failing sample inside a chunk leaves exactly ONE hole (its slot);
  chunk-mates are unaffected (per-item error holes, ``OnError.SKIP``).
* In-flight memory grows from ``concurrency`` samples to
  ``concurrency × chunk`` samples per chunked stage, plus inter-stage
  queues widened to ``chunk`` — still item *references*, not pixel data;
  pixels live in the fixed slab ring either way.
* The chunked binder binds slots inside the worker (``arena.slot_writer``),
  so arena backpressure blocks a pool thread rather than polling the loop;
  ``Pipeline.stop()`` still wakes it via the ``arena.close`` callback.

The hot path to the device (``transfer_chunk=``, default 2)
-----------------------------------------------------------
The batch → device leg is chunked too, on both ends of the sink:

* **Transfer stage**: with ``transfer_chunk > 1`` the transfer runs as a
  vectorized chunk stage (``DeviceTransfer.transfer_many``) — one executor
  call issues ``device_put`` (+ the fused on-chip decode, below) for a
  whole chunk of batches, in arrival order, amortizing the engine's
  per-batch hops over the largest items in the pipeline.
* **Sink drain**: consumers pull matching chunks with
  ``Pipeline.get_items(n)`` (or ``HealthMonitor.guard(chunk=n)``) — one
  cross-thread round trip drains up to *n* buffered batches.  Ordering is
  preserved end to end: ``get_items`` returns batches exactly in emission
  order, and mixing ``get_item``/``get_items`` calls on the same pipeline
  is safe (they share one stash; a timed-out call never loses the batch it
  was waiting on).
* **Memory**: every batch parked in the ``chunk``-widened batch→transfer
  queue pins a slab, and up to ``transfer_chunk - 1`` dispatched-but-unput
  batches sit in the transfer worker mid-chunk, so both the transfer's
  hold window (``consumer_window + 1 + transfer_chunk``) and the arena's
  deadlock floor (see ``_ring_size``) grow with ``transfer_chunk``.
  Slabs still recycle per batch, in order, chunked or not.
* **Failure**: the vectorized stage fails whole-chunk — a ``device_put``
  error poisons its chunk-mates (they were dispatched by the same call).
  Batches are few and transfers don't fail per-sample, so this trades an
  irrelevant failure granularity for the hop amortization.

With ``device_decode=DeviceDecode(mean, std, ...)`` the loader ships
**uint8 wire bytes end to end**: slab rows stay uint8 through collate and
transfer, and the fused ``dequant_normalize_augment`` kernel (uint8→bf16
dequant, per-channel normalize, flip/crop augment, one VMEM pass) runs
on-chip right after ``device_put`` — zero host-side float math on pixels.
See ``data/transfer.py`` and ``kernels/dequant_normalize.py``.

**Checkpoint skip bound under chunking**: samples accumulate inside
in-flight chunks before they reach a delivered batch, so a sampler
checkpoint taken mid-stream can additionally skip the samples resident in
chunked stages — at most ``chunk`` per unit of stage concurrency plus the
``chunk``-widened queues.  On the default wiring that is
``(max(read_concurrency, decode_concurrency) + 3) × chunk`` samples (the
fused read+decode stage runs at the max of the two concurrencies) — on
top of the sink-buffered batches (sampler.py), the ``2 × transfer_chunk``
batches the chunked transfer leg can hold (its widened input queue plus
the dispatch chunk in flight), and, on the prefetcher path, the
``_PREFETCH_LOOKAHEAD`` window below.
Still bounded and epoch-local; set ``chunk=1`` to restore the narrow
per-item bound when checkpoint tightness matters more than throughput.

Sharded datasets (``repro.data.shards``)
----------------------------------------
Both loaders accept a ``ShardDataset`` unchanged: its ``read_bytes`` hands
back a ``memoryview`` of the shard's mmap and the zero-copy path
decompresses it straight into a slab slot (mmap → ``decode_into`` → arena,
no intermediate copies).  When the dataset carries a ``ShardPrefetcher``
(remote mode), the index source is wrapped so upcoming shards are fetched
in the background ``_PREFETCH_LOOKAHEAD`` samples ahead of the read stage,
and the prefetcher's cache counters surface on the read stage's row in
``Pipeline.stats()``.  Pair with the sampler's shard-aware shuffle
(``shard_sizes=dataset.shard_sizes``) so consecutive samples share shards
and the cache actually hits.  At multi-rank scale, construct the dataset
with ``peers=[...]`` (other ranks' ``PeerShardServer`` URLs): a cache miss
then tries the peers' warm caches before the origin, and the read stage's
dashboard row grows ``peer_hits``/``origin_bytes`` (see
``repro.data.shards.peer``).

Columnar (format v2) shards add **projection pushdown**:
``build_image_loader(ds, fields=("image",))`` reads only the named column
— the read stage's zero-copy view covers just that field's bytes, and on
the prefetcher path the field name rides the lookahead hints so sparse
fetches pull only that column's byte ranges off the wire (``bytes_skipped``
on the dashboard counts what projection saved).  A ``ShardDataset``
constructed with its own ``fields=`` projection gets the same hint wiring
automatically in both loaders.

Checkpoint caveat: the lookahead wrapper holds up to ``_PREFETCH_LOOKAHEAD``
already-drawn indices that the sampler has counted as handed out, so a
sampler checkpoint taken mid-stream on the prefetcher path skips at most
``_PREFETCH_LOOKAHEAD`` samples *in addition to* the sink-buffered batches
documented in ``sampler.py`` — still bounded and epoch-local, but wider
than the local-dataset path.

Failure semantics (what a bad sample / slow sample / dead backend does)
-----------------------------------------------------------------------
The loaders inherit the engine's failure contract (see the "Failure
semantics" section of ``core/engine.py``) and add the storage layer's:

* **Corrupt sample** (unreadable bytes, malformed codec blob): the read or
  decode stage raises, the item becomes a hole under ``OnError.SKIP`` —
  one missing sample, never a torn batch (on the zero-copy path the slot
  is ``mark_hole``-ed so its batch still completes).  Fail-fast stages
  raise ``PipelineFailure`` carrying the *phase* name (``read``/
  ``decode``), the fused stage name, and the item's stage-stream index.
* **Slow sample** (storage tail, contended decode): with
  ``straggler_after=`` the slow lane detaches it so chunk-mates emit on
  time; its result re-enters at its original position.  Batches stay
  in-order and complete — straggling costs latency on ONE batch instead
  of throughput on all of them.  ``Pipeline.stats()`` shows ``stragglers``
  / ``straggler_shed`` per stage.
* **Truncated transfer** (backend dies mid-body): ``HttpShardSource``
  validates ``Content-Length`` and surfaces a retryable
  ``SourceUnavailable`` — a short body is *never* installed into the
  shard cache (``RetryingSource`` covers the retry).
* **Dead peer** (multi-rank): the peer tier's circuit breaker benches it
  (half-open probe after ``cooldown_s``), fetches fall through to the
  origin; with ``hedge_after_s`` a merely *slow* peer is raced against
  the origin instead of waited out.
* **Stall** (no batch progressing at all): wrap consumption in
  ``core.HealthMonitor.guard()`` — degradation actions (disable eager
  verify, widen the sparse threshold, go origin-only) fire first, then a
  structured ``PipelineStalled`` names the suspect stage.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Iterable, Iterator

import numpy as np

from ..core import Pipeline, PipelineBuilder
from .arena import SlabArena
from .codec import (
    decode_into,
    decode_sample,
    parse_header,
    resize_nearest,
    resize_nearest_into,
)
from .packing import SequencePacker, collate
from .sampler import CheckpointableSampler
from .transfer import DeviceDecode, DeviceTransfer


def _ring_size(
    arena_slabs: int | None, transfer: DeviceTransfer, transfer_chunk: int = 2
) -> int:
    """Slab-ring size for a loader: the ring must outsize the slabs pinned
    at once (transfer hold + inter-stage queues + the one being filled) or
    the binder deadlocks the pipeline.  The batch→transfer queue is widened
    to the transfer stage's chunk (so the chunked drain can actually fill
    its chunks), and every batch parked there pins a slab — the floor
    grows with ``transfer_chunk`` past the default 2.  An explicit request
    below the floor is an error, not a silent inflation — the caller set
    it as a memory cap and must raise it (or the sink buffer) knowingly."""
    in_flight = 2 + max(2, transfer_chunk)  # queue + assembling + mid-transfer
    floor = transfer.hold_slabs + in_flight
    if arena_slabs is None:
        return floor
    if arena_slabs < floor:
        raise ValueError(
            f"arena_slabs={arena_slabs} is below the deadlock floor "
            f"{floor} (= transfer hold {transfer.hold_slabs} + {in_flight} "
            "in-flight); raise arena_slabs or lower sink_buffer/transfer_chunk"
        )
    return arena_slabs


def _pipe_transfer(
    builder: PipelineBuilder, transfer: DeviceTransfer, transfer_chunk: int
) -> PipelineBuilder:
    """Wire the terminal transfer stage (§2.1: exactly one transfer task).

    ``transfer_chunk > 1`` dispatches a drained chunk of batches per engine
    hop (``transfer_many`` as a vectorized chunk stage) — one executor call
    issues the whole chunk's ``device_put`` (+ fused decode) dispatches in
    order.  The ``cache=transfer`` probe surfaces ``device_decode_ms`` /
    ``device_decode_batches`` on the transfer stage's stats row."""
    if transfer_chunk > 1:
        return builder.pipe(
            transfer.transfer_many, concurrency=1, name="transfer",
            chunk=transfer_chunk, vectorized=True, cache=transfer,
        )
    return builder.pipe(transfer, concurrency=1, name="transfer", cache=transfer)


#: how many samples of headroom the shard-prefetch wrapper keeps between
#: scheduling a shard's fetch and handing its first index to the pipeline —
#: the slack that lets the download overlap the decode of earlier shards.
_PREFETCH_LOOKAHEAD = 64


def _with_shard_prefetch(
    indices: Iterable[int],
    dataset: Any,
    lookahead: int = _PREFETCH_LOOKAHEAD,
    fields: tuple[str, ...] | None = None,
) -> Iterator[int]:
    """Index-stream wrapper for prefetcher-backed shard datasets: peek
    ``lookahead`` samples ahead of what the pipeline has been handed and
    schedule background fetches for the shards they live in, so by the time
    the read stage asks for a sample its shard is (usually) already in the
    local cache.  Scheduling is advisory — a dropped request just means the
    read stage fetches on demand.

    Index-first sources (``prefetcher.index_first``): instead of scheduling
    a whole-shard fetch on first sight, the wrapper accumulates the *run*
    of consecutive same-shard indices the sampler emits (the shard-aware
    shuffle makes runs the common case) and schedules the shard with those
    shard-local indices as ``samples=`` hints — the prefetcher then pulls
    the shard's header + index and fetches only the hinted sample ranges
    when they cover a small fraction of the payload.  A run that grows past
    ``lookahead`` clearly wants most of the shard, so it is committed early
    as a whole-shard fetch.

    The buffered indices have already advanced the sampler's cursor, so a
    checkpoint taken mid-stream treats them as consumed: resume skips at
    most ``lookahead`` samples beyond the sink-buffered batches (see the
    module docstring's checkpoint caveat).

    ``fields`` (columnar v2 shards) rides every hint: a sparse fetch then
    coalesces ranges over the requested columns only, so projection
    pushdown reaches the wire from here."""
    pf = dataset.prefetcher
    want_hints = bool(getattr(pf, "index_first", False))
    buf: deque[int] = deque()
    run_shard = -1
    run_samples: list[int] | None = []  # None = run already committed full

    def schedule(shard: int, samples=None) -> None:
        if fields is not None:
            pf.schedule(dataset.shard_names[shard], samples=samples, fields=fields)
        else:
            pf.schedule(dataset.shard_names[shard], samples=samples)

    def commit_run() -> None:
        if run_shard >= 0 and run_samples:
            schedule(run_shard, run_samples)

    for i in indices:
        shard, local = dataset.shard_and_offset(i)
        if shard != run_shard:  # run boundary; pf.schedule also dedups
            commit_run()
            run_shard, run_samples = shard, []
            if not want_hints:
                # no ranged reads available: schedule the whole shard as
                # early as possible (maximum fetch/decode overlap)
                schedule(shard)
                run_samples = None
        if want_hints and run_samples is not None:
            run_samples.append(local)
            if len(run_samples) >= lookahead:
                # the window wants most of this shard: commit to a full
                # fetch now rather than waiting for the run to end
                schedule(shard)
                run_samples = None
        buf.append(i)
        if len(buf) > lookahead:
            yield buf.popleft()
    commit_run()
    yield from buf


def _maybe_prefetch(
    indices: Iterable[int], dataset: Any, fields: tuple[str, ...] | None = None
) -> tuple[Iterable[int], Any]:
    """(index stream, cache probe) — wired only for prefetcher datasets.
    ``fields=None`` falls back to the dataset's own projection, so a
    ``ShardDataset(fields=...)`` hints its columns without loader help."""
    prefetcher = getattr(dataset, "prefetcher", None)
    if prefetcher is None:
        return indices, None
    if fields is None:
        fields = getattr(dataset, "fields", None)
    return _with_shard_prefetch(indices, dataset, fields=fields), prefetcher


def build_image_loader(
    dataset,
    *,
    batch_size: int = 32,
    hw: tuple[int, int] = (224, 224),
    read_concurrency: int = 4,
    decode_concurrency: int = 4,
    num_threads: int = 8,
    sink_buffer: int = 3,
    shardings: Any | None = None,
    uint8_wire: bool = True,
    sampler: CheckpointableSampler | None = None,
    epochs: int | None = 1,  # None = stream forever (training);  N = bounded
    zero_copy: bool = True,
    arena_slabs: int | None = None,  # None = sized from the consumer window
    chunk: int = 16,  # items per executor dispatch; 1 = per-item path
    fuse_stages: bool = True,  # collapse read+decode into one worker call
    straggler_after: float | None = None,  # soft deadline on read/decode
    trace=None,  # core.trace.Tracer: flight-recorder spans for every layer
    fields: tuple[str, ...] | None = None,  # columnar projection, e.g. ("image",)
    device_decode: DeviceDecode | None = None,  # on-chip fused decode tail
    transfer_chunk: int = 2,  # batches per transfer dispatch; 1 = per-batch
) -> Pipeline:
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if transfer_chunk < 1:
        raise ValueError("transfer_chunk must be >= 1")
    if straggler_after is not None and chunk <= 1:
        raise ValueError("straggler_after requires chunk > 1 (see pipe())")
    # Columnar projection: this pipeline decodes exactly one image blob per
    # sample, so the projection must name exactly one field.  The name is
    # pushed down every layer — the read stage pulls only that column, the
    # prefetch hints carry it to the wire, and multi-field shards stop
    # paying fetch+decode for the columns this loader never touches.
    if fields is not None:
        fields = tuple(fields)
        if len(fields) != 1:
            raise ValueError(
                f"the image pipeline decodes one field per sample; "
                f"fields={list(fields)} names {len(fields)}"
            )
        if getattr(dataset, "schema_fields", None) is None:
            raise TypeError(
                "fields= needs a columnar (format v2) ShardDataset — "
                "migrate with pack(..., format_version=2)"
            )
    # fusion widens both stages to max(read, decode) concurrency — a
    # concurrency-1 stage may be deliberate (serialization), so don't
    fuse_stages = fuse_stages and (
        min(read_concurrency, decode_concurrency) > 1
        or read_concurrency == decode_concurrency
    )
    sampler = sampler or CheckpointableSampler(len(dataset), batch_size=1, shuffle=False)

    def indices():
        limit = None if epochs is None else sampler.batches_per_epoch() * epochs
        for k, batch in enumerate(sampler):
            if limit is not None and k >= limit:
                return
            yield from batch

    transfer = DeviceTransfer(
        shardings, uint8_wire=uint8_wire, consumer_window=sink_buffer,
        dispatch_chunk=transfer_chunk, device_decode=device_decode,
        tracer=trace,
    )

    index_stream, cache_probe = _maybe_prefetch(indices(), dataset, fields=fields)

    if fields is not None:
        _field = fields[0]

        def read_blob(i: int) -> memoryview:
            # projected read: only this column's bytes (zero-copy view)
            return dataset.read_fields(i, fields)[_field]
    else:
        read_blob = dataset.read_bytes

    if zero_copy and len(dataset) > 0:
        # The slab spec hard-codes uint8 (H, W, 3) slots.  A dataset of
        # incompatible samples (grayscale, float, video clips) would hole
        # out EVERY item under OnError.SKIP — a silent empty epoch — so
        # sniff one sample and fall back to list-collate instead.  Shard
        # manifests record sample 0's layout (per field on columnar
        # manifests), which answers the question without reading data (a
        # remote dataset would otherwise download a whole shard for this
        # one header).
        meta = (
            dataset.field_meta(fields[0])
            if fields is not None and callable(getattr(dataset, "field_meta", None))
            else getattr(dataset, "sample_meta", None)
        )
        if meta is not None:
            dtype, shape = meta
            if len(shape) != 3 or shape[2] != 3 or dtype != np.uint8:
                zero_copy = False
        else:
            try:
                probe = decode_sample(read_blob(0))
            except Exception:
                pass  # unreadable first sample: the runtime path will skip it
            else:
                if probe.ndim != 3 or probe.shape[2] != 3 or probe.dtype != np.uint8:
                    zero_copy = False

    if not zero_copy:
        # Classic list-collate fallback: each decode allocates its own
        # output, the collate stage allocates a fresh slab per batch.
        def read(i: int) -> bytes:
            return read_blob(i)

        def decode(data: bytes) -> np.ndarray:
            img = decode_sample(data)
            return resize_nearest(img, hw)

        def make_batch(imgs: list[np.ndarray]) -> dict:
            out = np.empty((len(imgs), *imgs[0].shape), imgs[0].dtype)
            for j, im in enumerate(imgs):
                out[j] = im
            return {"images": out}

        builder = (
            PipelineBuilder()
            .add_source(index_stream, name="sampler")
            .pipe(read, concurrency=read_concurrency, name="read",
                  cache=cache_probe, chunk=chunk,
                  straggler_after=straggler_after)
            .pipe(decode, concurrency=decode_concurrency, name="decode",
                  chunk=chunk, straggler_after=straggler_after)
        )
        if fuse_stages:
            builder.fuse("read", "decode")
        builder = builder.aggregate(
            batch_size, drop_last=True, name="batch"
        ).pipe(make_batch, name="collate")
        return (
            _pipe_transfer(builder, transfer, transfer_chunk)
            .add_sink(buffer_size=sink_buffer)
            .build(num_threads=num_threads, trace=trace)
        )

    # Zero-copy slab path (see module docstring "Memory model").
    arena = SlabArena(
        {"images": ((*hw, 3), np.uint8)},
        batch_size=batch_size,
        num_slabs=_ring_size(arena_slabs, transfer, transfer_chunk),
    )

    def read(item) -> tuple:
        i, ref = item
        try:
            return read_blob(i), ref
        except Exception:
            ref.mark_hole()  # the slot was already assigned; don't leak it
            raise

    def decode(item):
        data, ref = item
        try:
            out = ref.slab.arrays["images"][ref.slot]
            dtype, shape, _ = parse_header(data)
            if tuple(shape) == tuple(out.shape) and dtype == out.dtype:
                decode_into(data, out)  # native size: decompress into the slot
            else:
                resize_nearest_into(decode_sample(data), out)
            return ref
        except Exception:
            ref.mark_hole()  # the row will never arrive; unblock the batch
            raise

    builder = PipelineBuilder().add_source(index_stream, name="sampler")
    if chunk > 1:
        # chunked binder: one executor call assigns N slots in order (the
        # stage is concurrency=1 and order-preserving, so the stateful
        # cursor is single-writer).  Arena exhaustion blocks the worker
        # thread — the same backpressure, minus a loop poll per item.
        next_slot = arena.slot_writer()

        def bind(item):
            return item, next_slot()

        builder.pipe(bind, concurrency=1, name="slot", chunk=chunk)
    else:
        builder.pipe(arena.binder(), concurrency=1, name="slot")  # blocks = backpressure
    builder.pipe(
        read, concurrency=read_concurrency, name="read",
        cache=cache_probe, chunk=chunk, straggler_after=straggler_after,
    ).pipe(
        decode, concurrency=decode_concurrency, name="decode", chunk=chunk,
        straggler_after=straggler_after,
        # the batch stage drains via get_many: a chunk-wide queue of slot
        # REFS (tickets, not pixels) lets it amortize its loop hops too
        queue_size=max(2, chunk),
    )
    if fuse_stages:
        builder.fuse("read", "decode")
    builder = builder.aggregate_into(arena, batch_size, drop_last=True, name="batch")
    pipe = (
        _pipe_transfer(builder, transfer, transfer_chunk)
        .add_sink(buffer_size=sink_buffer)
        .build(num_threads=num_threads, trace=trace)
    )
    pipe.add_stop_callback(arena.close)
    pipe.add_stop_callback(transfer.flush)
    return pipe


def build_lm_loader(
    dataset,
    *,
    seq_len: int,
    batch_size: int,
    sampler: CheckpointableSampler | None = None,
    read_concurrency: int = 4,
    decode_concurrency: int = 4,
    num_threads: int = 8,
    sink_buffer: int = 2,
    shardings: Any | None = None,
    seed: int = 0,
    zero_copy: bool = True,
    arena_slabs: int | None = None,  # None = sized from the consumer window
    chunk: int = 16,  # items per executor dispatch; 1 = per-item path
    straggler_after: float | None = None,  # soft deadline on the read stage
    trace=None,  # core.trace.Tracer: flight-recorder spans for every layer
    transfer_chunk: int = 2,  # batches per transfer dispatch; 1 = per-batch
) -> tuple[Pipeline, CheckpointableSampler]:
    """Returns (pipeline, sampler) — the sampler is checkpointed alongside
    model state (fault tolerance; see runtime/trainer.py).

    The zero-copy path packs rows straight into a packed-rows slab (one
    ``(batch, seq_len) int32`` buffer per field) and skips the collate stage
    entirely; see the module docstring for the slab ownership rules.

    ``chunk`` applies to the read and decode+pack stages (the packer stage
    stays ``concurrency=1`` — ordered chunk dispatch keeps its state
    single-writer — and is NOT fused with the wider read stage).  The
    module docstring's chunked checkpoint-bound caveat applies.

    ``straggler_after`` arms the slow lane on the *read* stage only (a
    slow shard fetch is the dominant tail here); the packer stage is
    stateful, which the slow lane's item-major execution cannot support.
    """
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if transfer_chunk < 1:
        raise ValueError("transfer_chunk must be >= 1")
    if straggler_after is not None and chunk <= 1:
        raise ValueError("straggler_after requires chunk > 1 (see pipe())")
    sampler = sampler or CheckpointableSampler(
        len(dataset), batch_size=8, seed=seed, shuffle=True
    )
    packer = SequencePacker(seq_len)

    def doc_ids():
        for batch in sampler:
            yield from batch

    def read(i: int) -> bytes:
        return dataset.read_bytes(i)

    transfer = DeviceTransfer(
        shardings, consumer_window=sink_buffer,
        dispatch_chunk=transfer_chunk, tracer=trace,
    )
    doc_stream, cache_probe = _maybe_prefetch(doc_ids(), dataset)

    if not zero_copy:
        def pack(data: bytes) -> list[dict]:
            doc = decode_sample(data)
            return packer.add(doc)  # 0..k completed rows

        builder = (
            PipelineBuilder()
            .add_source(doc_stream, name="sampler")
            .pipe(read, concurrency=read_concurrency, name="read",
                  cache=cache_probe, chunk=chunk,
                  straggler_after=straggler_after)
            .pipe(pack, concurrency=1, name="decode+pack", chunk=chunk)  # stateful
            .disaggregate(name="rows")
            .aggregate(batch_size, drop_last=True, name="batch")
            .pipe(collate, concurrency=decode_concurrency, name="collate")
        )
        pipe = (
            _pipe_transfer(builder, transfer, transfer_chunk)
            .add_sink(buffer_size=sink_buffer)
            .build(num_threads=num_threads, trace=trace)
        )
        return pipe, sampler

    row_shape = ((seq_len,), np.int32)
    arena = SlabArena(
        {k: row_shape for k in ("tokens", "labels", "positions", "segment_ids")},
        batch_size=batch_size,
        num_slabs=_ring_size(arena_slabs, transfer, transfer_chunk),
    )
    next_slot = arena.slot_writer()  # only touched by the concurrency=1 packer

    def pack_into(data: bytes) -> list:
        doc = decode_sample(data)
        return packer.add_into(doc, next_slot)  # 0..k completed slot tickets

    builder = (
        PipelineBuilder()
        .add_source(doc_stream, name="sampler")
        .pipe(read, concurrency=read_concurrency, name="read",
              cache=cache_probe, chunk=chunk,
              straggler_after=straggler_after)
        .pipe(pack_into, concurrency=1, name="decode+pack", chunk=chunk)  # stateful
        .disaggregate(name="rows")
        .aggregate_into(arena, batch_size, drop_last=True, name="batch")
    )
    pipe = (
        _pipe_transfer(builder, transfer, transfer_chunk)
        .add_sink(buffer_size=sink_buffer)
        .build(num_threads=num_threads, trace=trace)
    )
    pipe.add_stop_callback(arena.close)
    pipe.add_stop_callback(transfer.flush)
    return pipe, sampler

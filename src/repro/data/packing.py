"""Sequence packing: pack variable-length docs into fixed (seq_len,) rows.

Emits the packed tokens + next-token labels + positions (restarting per
document) + segment ids (for the block-diagonal causal mask the attention
layers honor via ``segment_ids``) — no cross-document attention leakage,
no padding waste beyond row tails.

Two emission paths share one walk (``_emit_into``):

``add(doc)``                 — classic: returns freshly allocated row dicts;
``add_into(doc, next_slot)`` — zero-copy: writes each completed row straight
                               into an arena slab slot (``SlotRef.views()``)
                               and returns the slot tickets.  The packer
                               keeps one reusable (seq_len+1,) scratch per
                               field, so steady-state packing allocates
                               nothing per row.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

import numpy as np


class SequencePacker:
    def __init__(self, seq_len: int, pad_id: int = 0):
        self.seq_len = seq_len
        self.pad_id = pad_id
        self._buf: list[np.ndarray] = []
        self._buf_len = 0
        # reusable scratch: one extra token for the label shift
        n = seq_len + 1
        self._toks = np.empty(n, np.int32)
        self._segs = np.empty(n, np.int32)
        self._pos = np.empty(n, np.int32)
        self._arange = np.arange(n, dtype=np.int32)
        self._same = np.empty(seq_len, bool)

    def add(self, doc: np.ndarray) -> list[dict]:
        """Feed one document; returns zero or more completed rows."""
        out = []
        self._push(doc)
        while self._buf_len >= self.seq_len + 1:  # +1 for the label shift
            row = {
                "tokens": np.empty(self.seq_len, np.int32),
                "labels": np.empty(self.seq_len, np.int32),
                "positions": np.empty(self.seq_len, np.int32),
                "segment_ids": np.empty(self.seq_len, np.int32),
            }
            self._emit_into(row)
            out.append(row)
        return out

    def add_into(self, doc: np.ndarray, next_slot: Callable[[], Any]) -> list:
        """Feed one document, writing completed rows into slab slots.

        ``next_slot()`` must return a ticket exposing ``views()`` (e.g.
        ``repro.data.arena.SlotRef``); the completed tickets are returned in
        emission order.
        """
        out = []
        self._push(doc)
        while self._buf_len >= self.seq_len + 1:
            ref = next_slot()
            self._emit_into(ref.views())
            out.append(ref)
        return out

    # ------------------------------------------------------------------
    def _push(self, doc: np.ndarray) -> None:
        self._buf.append(doc.astype(np.int32))
        self._buf_len += len(doc)

    def _emit_into(self, out: Mapping[str, np.ndarray]) -> None:
        """Fill one packed row into ``out``'s (seq_len,) arrays in place."""
        L = self.seq_len
        toks, segs, pos = self._toks, self._segs, self._pos
        write = 0
        seg = 0
        while write < L + 1:
            head = self._buf[0]
            use = min(len(head), L + 1 - write)
            toks[write : write + use] = head[:use]
            segs[write : write + use] = seg
            pos[write : write + use] = self._arange[:use]
            if use == len(head):
                self._buf.pop(0)
                self._buf_len -= use
                seg += 1
            else:
                # keep the remainder; overlap 1 token so labels stay aligned
                self._buf[0] = head[use - 1 :]
                self._buf_len -= use - 1
            write += use
        out["tokens"][:] = toks[:L]
        out["labels"][:] = toks[1 : L + 1]
        # mask labels that cross a segment boundary (next token is a new doc)
        np.equal(segs[1 : L + 1], segs[:L], out=self._same)
        np.logical_not(self._same, out=self._same)
        out["labels"][self._same] = -1
        out["positions"][:] = pos[:L]
        out["segment_ids"][:] = segs[:L]


def collate(rows: list[dict]) -> dict:
    """Stack rows into a batch, writing into one contiguous allocation per
    key (the paper's §2.1 batching rule: allocate once, copy once)."""
    out = {}
    for key in rows[0]:
        first = np.asarray(rows[0][key])
        batch = np.empty((len(rows), *first.shape), first.dtype)
        for i, r in enumerate(rows):
            batch[i] = r[key]
        out[key] = batch
    return out

"""Sequence packing: pack variable-length docs into fixed (seq_len,) rows.

Emits the packed tokens + next-token labels + positions (restarting per
document) + segment ids (for the block-diagonal causal mask the attention
layers honor via ``segment_ids``) — no cross-document attention leakage,
no padding waste beyond row tails.
"""

from __future__ import annotations

import numpy as np


class SequencePacker:
    def __init__(self, seq_len: int, pad_id: int = 0):
        self.seq_len = seq_len
        self.pad_id = pad_id
        self._buf: list[np.ndarray] = []
        self._buf_len = 0

    def add(self, doc: np.ndarray) -> list[dict]:
        """Feed one document; returns zero or more completed rows."""
        out = []
        self._buf.append(doc.astype(np.int32))
        self._buf_len += len(doc)
        while self._buf_len >= self.seq_len + 1:  # +1 for the label shift
            out.append(self._emit())
        return out

    def _emit(self) -> dict:
        need = self.seq_len + 1
        taken: list[np.ndarray] = []
        seg_ids = []
        positions = []
        seg = 0
        while need > 0:
            head = self._buf[0]
            use = min(len(head), need)
            taken.append(head[:use])
            seg_ids.append(np.full(use, seg, np.int32))
            positions.append(np.arange(use, dtype=np.int32))
            if use == len(head):
                self._buf.pop(0)
                self._buf_len -= use
                seg += 1
            else:
                # keep the remainder; overlap 1 token so labels stay aligned
                self._buf[0] = head[use - 1 :]
                self._buf_len -= use - 1
            need -= use
        toks = np.concatenate(taken)
        segs = np.concatenate(seg_ids)
        pos = np.concatenate(positions)
        tokens = toks[: self.seq_len]
        labels = toks[1 : self.seq_len + 1].copy()
        # mask labels that cross a segment boundary (next token is a new doc)
        same_seg = segs[1 : self.seq_len + 1] == segs[: self.seq_len]
        labels = np.where(same_seg, labels, -1)
        return {
            "tokens": tokens,
            "labels": labels,
            "positions": pos[: self.seq_len],
            "segment_ids": segs[: self.seq_len],
        }


def collate(rows: list[dict]) -> dict:
    """Stack rows into a batch, writing into one contiguous allocation per
    key (the paper's §2.1 batching rule: allocate once, copy once)."""
    out = {}
    for key in rows[0]:
        first = np.asarray(rows[0][key])
        batch = np.empty((len(rows), *first.shape), first.dtype)
        for i, r in enumerate(rows):
            batch[i] = r[key]
        out[key] = batch
    return out

"""Optimizers: AdamW (fp32 or bf16 moments), SGD-momentum, Adafactor.

Pure pytree transforms — optimizer state inherits parameter shardings, which
is exactly ZeRO-1/3 when params are FSDP-sharded (DESIGN §5).  ``adamw_bf16``
halves moment memory for the ≥100B architectures; Adafactor's factored
second moment is the fallback when even that does not fit.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adamw_bf16 | sgdm | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def _moment_dtype(cfg: OptConfig):
    return jnp.bfloat16 if cfg.kind == "adamw_bf16" else jnp.float32


def _factored(p) -> dict:
    if p.ndim >= 2:
        return {
            "vr": jnp.zeros(p.shape[:-1], jnp.float32),
            "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),
        }
    return {"v": jnp.zeros(p.shape, jnp.float32)}


def init_opt_state(cfg: OptConfig, params: Any) -> dict:
    if cfg.kind in ("adamw", "adamw_bf16"):
        mdt = _moment_dtype(cfg)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        }
    if cfg.kind == "sgdm":
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
    if cfg.kind == "adafactor":
        return {"step": jnp.zeros((), jnp.int32), "f": jax.tree.map(_factored, params)}
    raise ValueError(cfg.kind)


def abstract_opt_state(cfg: OptConfig, abstract_params: Any) -> dict:
    """ShapeDtypeStruct mirror of init_opt_state (for AOT lowering)."""

    def zs(p, dt=None):
        return jax.ShapeDtypeStruct(p.shape, dt or p.dtype)

    if cfg.kind in ("adamw", "adamw_bf16"):
        mdt = _moment_dtype(cfg)
        return {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "m": jax.tree.map(lambda p: zs(p, mdt), abstract_params),
            "v": jax.tree.map(lambda p: zs(p, mdt), abstract_params),
        }
    if cfg.kind == "sgdm":
        return {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "m": jax.tree.map(lambda p: zs(p, jnp.float32), abstract_params),
        }
    if cfg.kind == "adafactor":
        def fac(p):
            if len(p.shape) >= 2:
                return {
                    "vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                    "vc": jax.ShapeDtypeStruct((*p.shape[:-2], p.shape[-1]), jnp.float32),
                }
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}

        return {"step": jax.ShapeDtypeStruct((), jnp.int32), "f": jax.tree.map(fac, abstract_params)}
    raise ValueError(cfg.kind)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_update(cfg: OptConfig, params: Any, grads: Any, state: dict) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, opt_metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) if cfg.clip_norm else 1.0
    metrics = {"grad_norm": gnorm, "lr": lr}

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)

    if cfg.kind in ("adamw", "adamw_bf16"):
        mdt = _moment_dtype(cfg)
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        m_leaves = treedef.flatten_up_to(state["m"])
        v_leaves = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
            new_m.append(m32.astype(mdt))
            new_v.append(v32.astype(mdt))
        return (
            treedef.unflatten(new_p),
            {"step": step, "m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v)},
            metrics,
        )

    if cfg.kind == "sgdm":
        m_leaves = treedef.flatten_up_to(state["m"])
        new_p, new_m = [], []
        for p, g, m in zip(p_leaves, g_leaves, m_leaves):
            g = g.astype(jnp.float32) * scale + cfg.weight_decay * p.astype(jnp.float32)
            m32 = 0.9 * m + g
            new_p.append((p.astype(jnp.float32) - lr * m32).astype(p.dtype))
            new_m.append(m32)
        return (
            treedef.unflatten(new_p),
            {"step": step, "m": treedef.unflatten(new_m)},
            metrics,
        )

    if cfg.kind == "adafactor":
        d = 1e-30
        f_leaves = treedef.flatten_up_to(state["f"])
        new_p, new_f = [], []
        for p, g, f in zip(p_leaves, g_leaves, f_leaves):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + d
            if p.ndim >= 2:
                vr = 0.999 * f["vr"] + 0.001 * g2.mean(axis=-1)
                vc = 0.999 * f["vc"] + 0.001 * g2.mean(axis=-2)
                denom = (
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], d)
                )
                upd = g / (jnp.sqrt(denom) + cfg.eps)
                newf = {"vr": vr, "vc": vc}
            else:
                v = 0.999 * f["v"] + 0.001 * g2
                upd = g / (jnp.sqrt(v) + cfg.eps)
                newf = {"v": v}
            newp = (
                p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype)
            new_p.append(newp)
            new_f.append(newf)
        return (
            treedef.unflatten(new_p),
            {"step": step, "f": treedef.unflatten(new_f)},
            metrics,
        )

    raise ValueError(cfg.kind)

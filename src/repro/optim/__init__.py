from .optimizer import OptConfig, init_opt_state, apply_update, lr_schedule

__all__ = ["OptConfig", "init_opt_state", "apply_update", "lr_schedule"]

"""Batched serving runtime: SPDL request pipeline → prefill → decode loop.

Requests stream through an SPDL pipeline (tokenize/pad happen on the worker
pool, exactly like training-side loading); the server runs a jitted prefill
on each full batch and then greedy decode steps against the shared KV cache.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..core import PipelineBuilder
from ..data.tokenizer import ByteTokenizer
from ..launch.steps import build_decode_step, build_prefill_step


@dataclasses.dataclass
class ServeResult:
    prompt: str
    token_ids: list[int]
    text: bytes


class BatchServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_size: int = 4,
        prompt_len: int = 32,
        max_new: int = 16,
        mesh=None,
    ):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new = max_new
        shape = ShapeConfig("serve", prompt_len, batch_size, "prefill")
        dshape = ShapeConfig("serve_d", prompt_len + max_new, batch_size, "decode")
        self.prefill = build_prefill_step(cfg, mesh, shape).jitted
        self.decode = build_decode_step(cfg, mesh, dshape).jitted
        self.tok = ByteTokenizer(cfg.vocab_size)

    # -- request pipeline -----------------------------------------------------
    def _batches(self, prompts: Iterable[str]):
        def tokenize(p: str) -> dict:
            ids = self.tok.encode(p, add_eos=False)[: self.prompt_len]
            padded = np.zeros(self.prompt_len, np.int32)
            padded[-len(ids):] = ids  # left-pad so decode positions align
            return {"prompt": p, "tokens": padded}

        def to_batch(rows: list[dict]) -> dict:
            return {
                "prompts": [r["prompt"] for r in rows],
                "tokens": np.stack([r["tokens"] for r in rows]),
            }

        return (
            PipelineBuilder()
            .add_source(prompts, name="requests")
            .pipe(tokenize, concurrency=4, name="tokenize")
            .aggregate(self.batch_size, drop_last=False, name="batch")
            .pipe(to_batch, name="collate")
            .add_sink(buffer_size=2)
            .build(num_threads=4)
        )

    def generate(self, prompts: list[str]) -> list[ServeResult]:
        results: list[ServeResult] = []
        pipe = self._batches(prompts)
        with pipe.auto_stop():
            for batch in pipe:
                results.extend(self._generate_batch(batch))
        return results

    def _generate_batch(self, batch) -> list[ServeResult]:
        toks = batch["tokens"]
        b = toks.shape[0]
        if b < self.batch_size:  # pad the ragged tail batch
            toks = np.concatenate([toks, np.zeros((self.batch_size - b, toks.shape[1]), np.int32)])
        logits, caches = self.prefill(self.params, {"tokens": jnp.asarray(toks)})
        caches = self._grow_cache(caches)
        out_ids = [[] for _ in range(self.batch_size)]
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy
        for t in range(self.max_new):
            for i in range(self.batch_size):
                out_ids[i].append(int(cur[i]) if cur.ndim == 1 else int(cur[i, 0]))
            step_tokens = cur.reshape(self.batch_size, 1) if cur.ndim == 1 else cur[:, None, :]
            logits, caches = self.decode(
                self.params, caches, step_tokens, jnp.int32(self.prompt_len + t)
            )
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return [
            ServeResult(p, ids, self.tok.decode(np.array(ids)))
            for p, ids in zip(batch["prompts"], out_ids[:b])
        ]

    def _grow_cache(self, caches):
        """Pad prefill cache (len=prompt_len) to prompt_len+max_new capacity."""
        from ..models.model import Model

        model = Model(self.cfg)
        spec = model.cache_spec(self.batch_size, self.prompt_len + self.max_new)
        return jax.tree.map(
            lambda sp, x: jnp.pad(x, [(0, t - s) for s, t in zip(x.shape, sp.shape)]),
            spec,
            caches,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

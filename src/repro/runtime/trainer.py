"""Training runtime: fault-tolerant loop over an SPDL data pipeline.

Fault tolerance / scale features:
  - checkpoint/restart: periodic async checkpoints of params, optimizer,
    step AND the sampler cursor; ``Trainer.from_checkpoint`` resumes with
    exactly-once data consumption (property-tested).
  - straggler/starvation monitoring: wall-time split into data-wait vs
    step-time; the sink-occupancy signal from the pipeline identifies
    whether the loader or the step is the bottleneck, and a widening hook
    reports the recommended stage to re-tune (paper "Visibility" put to
    work at the trainer level).
  - the data pipeline runs on the scheduler thread + worker pool, so the
    main thread spends its time in jitted steps — GIL contention stays
    between exactly two Python threads (the paper's design, §5.1).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from ..ckpt import CheckpointManager, latest_step, load_checkpoint
from ..configs.base import ModelConfig, ShapeConfig
from ..core import Pipeline
from ..launch.steps import build_train_step, opt_config_for
from ..optim import init_opt_state

logger = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    ckpt_keep: int = 2
    log_every: int = 10
    starvation_threshold: float = 0.25  # data-wait fraction that flags the loader


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        *,
        mesh=None,
        tcfg: TrainerConfig | None = None,
        grad_accum: int | None = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg or TrainerConfig()
        self.bundle = build_train_step(cfg, mesh, shape, grad_accum=grad_accum)
        self.model = self.bundle.model
        self.opt_cfg = opt_config_for(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.opt_state = init_opt_state(self.opt_cfg, self.params)
        self.step = 0
        self.manager = CheckpointManager(
            self.tcfg.ckpt_dir, every=self.tcfg.ckpt_every, keep=self.tcfg.ckpt_keep
        )
        self.data_wait_s = 0.0
        self.step_s = 0.0

    # -- restart -----------------------------------------------------------
    @classmethod
    def from_checkpoint(
        cls, cfg, shape, *, sampler=None, mesh=None, tcfg=None, grad_accum=None
    ) -> "Trainer":
        t = cls(cfg, shape, mesh=mesh, tcfg=tcfg, grad_accum=grad_accum)
        if latest_step(t.tcfg.ckpt_dir) is not None:
            restored = load_checkpoint(t.tcfg.ckpt_dir, t.params, t.opt_state)
            t.params = restored["params"]
            t.opt_state = restored["opt_state"]
            t.step = restored["step"]
            if sampler is not None and restored["sampler"] is not None:
                sampler.load_state_dict(restored["sampler"])
            logger.info("resumed from step %d", t.step)
        return t

    # -- loop ---------------------------------------------------------------
    def fit(
        self,
        pipeline: Pipeline,
        *,
        steps: int,
        sampler=None,
        on_metrics: Callable[[int, dict], None] | None = None,
    ) -> dict:
        history: list[dict] = []
        it = iter(pipeline)
        target = self.step + steps
        while self.step < target:
            t0 = time.monotonic()
            try:
                batch = next(it)
            except StopIteration:
                logger.warning("pipeline exhausted at step %d", self.step)
                break
            t1 = time.monotonic()
            self.params, self.opt_state, metrics = self.bundle.jitted(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            t2 = time.monotonic()
            self.data_wait_s += t1 - t0
            self.step_s += t2 - t1
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == target:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m.update(self.health())
                history.append({"step": self.step, **m})
                if on_metrics:
                    on_metrics(self.step, m)
                logger.info("step %d %s", self.step, m)
            self.manager.maybe_save(
                self.step,
                self.params,
                self.opt_state,
                sampler.state_dict() if sampler is not None else None,
            )
        self.manager.wait()
        return {"history": history, **self.health()}

    # -- health / straggler signal -------------------------------------------
    def health(self) -> dict:
        total = self.data_wait_s + self.step_s
        frac = self.data_wait_s / total if total > 0 else 0.0
        return {
            "data_wait_frac": round(frac, 4),
            "starved": frac > self.tcfg.starvation_threshold,
        }

    def tuning_hint(self, pipeline: Pipeline) -> str:
        """Visibility-driven advice: which stage to widen when starved."""
        if not self.health()["starved"]:
            return "loader keeps up (sink occupancy healthy); no action"
        stats = pipeline.stats()
        busiest = max(stats, key=lambda s: s.occupancy)
        return (
            f"trainer is data-starved; bottleneck stage is {busiest.name!r} "
            f"(occupancy {busiest.occupancy:.0%}) — raise its concurrency "
            f"or the worker pool size"
        )

"""Elastic scaling: re-mesh a job onto a different device count.

Sharding rules are expressed against *logical* axes (dist/sharding.py), so
scaling in/out is: build the new mesh → new ParallelPlan → re-lower the same
step → re-place the checkpoint with the new NamedShardings.  The model axis
(TP=16) is kept fixed — it is baked into attention-head/expert divisibility —
and the data axes absorb the node-count change, which is how v5e slices are
actually resized.

``elastic_dryrun`` proves the re-mesh compiles for a degraded pod (e.g. two
failed hosts → 14×16 chips) without hardware — same contract as the main
dry-run.
"""

from __future__ import annotations

import jax

from ..configs.base import ModelConfig, ShapeConfig
from .. import configs
from ..launch.steps import build_step, params_shardings


def make_elastic_mesh(n_data: int, tp: int = 16) -> jax.sharding.Mesh:
    return jax.make_mesh(
        (n_data, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def elastic_dryrun(arch: str, shape_name: str, n_data: int) -> dict:
    """Lower + compile the step on a degraded (n_data × 16) mesh."""
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    # global batch must stay divisible by the new dp degree; shrink if needed
    if shape.kind == "train" and shape.global_batch % n_data:
        gb = (shape.global_batch // n_data) * n_data
        shape = ShapeConfig(shape.name, shape.seq_len, gb, shape.kind)
    mesh = make_elastic_mesh(n_data)
    bundle = build_step(cfg, mesh, shape)
    with mesh:
        compiled = bundle.jitted.lower(*bundle.in_specs).compile()
    ma = compiled.memory_analysis()
    return {
        "arch": arch,
        "shape": shape.name,
        "n_devices": mesh.devices.size,
        "global_batch": shape.global_batch,
        "peak_bytes_est": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
    }


def reshard(tree, model, old_plan, new_plan):
    """Re-place a param pytree onto a new mesh (checkpoint → new topology)."""
    new_sh = params_shardings(model, new_plan)
    return jax.tree.map(jax.device_put, tree, new_sh)

from .trainer import Trainer, TrainerConfig
from .server import BatchServer

__all__ = ["Trainer", "TrainerConfig", "BatchServer"]

"""Attention blocks: GQA/MHA (optionally qk-norm, QKV-bias) and DeepSeek MLA.

Three entry points per mechanism:
  - ``*_train``   : causal self-attention over the whole sequence (no cache);
  - ``*_prefill`` : same math, additionally returns the KV cache;
  - ``*_decode``  : one new token against a cache of ``seq_len`` positions.

Long sequences (> attn_chunk) use a jnp online-softmax (flash-style) scan
over KV chunks so the (S×S) score matrix is never materialized — the XLA
fallback of the Pallas flash kernel (kernels/flash_attention.py), and the
path the 512-device dry-run lowers on the CPU backend.

MLA decode uses the *absorbed* form: scores are taken directly against the
compressed latent cache (rank 512 + rope 64), which is the mechanism that
makes DeepSeek-V3 32k/500k decode memory-light.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from ..dist.hints import hint
from .layers import apply_rope, rms_norm_simple
from .params import ParamDef

NEG_INF = -2.0**30  # large finite negative: avoids NaN from (-inf) - (-inf)


# ---------------------------------------------------------------------------
# parameter defs
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None), dt),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None), dt),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None), dt),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed"), dt, fan_in_dims=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((h, hd), ("heads", None), dt, "zeros")
        p["bk"] = ParamDef((kv, hd), ("kv_heads", None), dt, "zeros")
        p["bv"] = ParamDef((kv, hd), ("kv_heads", None), dt, "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((hd,), (None,), jnp.float32, "ones")
        p["k_norm"] = ParamDef((hd,), (None,), jnp.float32, "ones")
    return p


def mla_defs(cfg: ModelConfig) -> dict:
    d, h, m = cfg.d_model, cfg.num_heads, cfg.mla
    assert m is not None
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamDef((d, m.q_lora_rank), ("embed", None), dt),
        "q_norm": ParamDef((m.q_lora_rank,), (None,), jnp.float32, "ones"),
        "w_uq": ParamDef((m.q_lora_rank, h, qk), (None, "heads", None), dt),
        "w_dkv": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None), dt),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), jnp.float32, "ones"),
        "w_uk": ParamDef((m.kv_lora_rank, h, m.qk_nope_head_dim), (None, "heads", None), dt),
        "w_uv": ParamDef((m.kv_lora_rank, h, m.v_head_dim), (None, "heads", None), dt),
        "wo": ParamDef((h, m.v_head_dim, d), ("heads", None, "embed"), dt, fan_in_dims=(0, 1)),
    }


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _plain_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, scale):
    """q: (B,Sq,K,G,hd), k/v: (B,Skv,K,hd). Returns (B,Sq,K,G,hd)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = (q_pos[:, :, None] >= kv_pos[:, None, :]) & (
        q_seg[:, :, None] == kv_seg[:, None, :]
    )  # (B,Sq,Skv)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o


def _kv_scan_attention(q, kc, vc, q_pos, pc, q_seg, gc, scale):
    """Online-softmax over pre-chunked KV for one q block.

    q: (B,Sq,K,G,hd);  kc/vc: (NC,B,ckv,K,hd);  pc/gc: (NC,B,ckv).
    Memory: O(Sq × ckv) scores per scan step — never (Sq × Skv).
    """
    bq, sq, kh, gh, hd = q.shape
    m0 = jnp.full((bq, kh, gh, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, kh, gh, sq), jnp.float32)
    a0 = jnp.zeros((bq, sq, kh, gh, vc.shape[-1]), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kx, vx, px, gx = xs  # (B,ckv,...)
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, kx, preferred_element_type=jnp.float32)
        s = s * scale
        mask = (q_pos[:, :, None] >= px[:, None, :]) & (
            q_seg[:, :, None] == gx[:, None, :]
        )
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(vx.dtype), vx).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc, gc))
    l = jnp.maximum(l, 1e-20)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def _chunked_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, scale, q_chunk, kv_chunk):
    """Flash-style double-chunked attention in pure jnp (XLA fallback of the
    Pallas kernel): an outer sequential map over q blocks, an inner
    online-softmax scan over kv chunks.  Peak score memory is
    O(q_chunk × kv_chunk) per (B,H); each q block is rematerialized in the
    backward pass instead of saving its inner-scan state."""
    b, skv = k.shape[0], k.shape[1]
    nkv = -(-skv // kv_chunk)
    pad = nkv * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-7)
    kc = k.reshape(b, nkv, kv_chunk, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, kv_chunk, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, nkv, kv_chunk).transpose(1, 0, 2)
    gc = kv_seg.reshape(b, nkv, kv_chunk).transpose(1, 0, 2)

    sq = q.shape[1]
    if sq <= q_chunk:
        return _kv_scan_attention(q, kc, vc, q_pos, pc, q_seg, gc, scale)

    nq = -(-sq // q_chunk)
    qpad = nq * q_chunk - sq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, qpad)), constant_values=-1)
        q_seg = jnp.pad(q_seg, ((0, 0), (0, qpad)), constant_values=-9)
    qr = q.reshape(b, nq, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4, 5)
    qpr = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    qsr = q_seg.reshape(b, nq, q_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one_q_block(args):
        qi, qpi, qsi = args
        return _kv_scan_attention(qi, kc, vc, qpi, pc, qsi, gc, scale)

    ys = jax.lax.map(one_q_block, (qr, qpr, qsr))  # (nq, B, qc, K, G, v_dim)
    out = ys.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_chunk, *ys.shape[3:])
    return out[:, :sq]


def _sdpa(cfg: ModelConfig, q, k, v, q_pos, kv_pos, q_seg, kv_seg):
    """Dispatch: plain for short sequences, double-chunked flash beyond."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    skv = k.shape[1]
    threshold = cfg.attn_chunk or 2048
    with jax.named_scope("attention"):  # census bucket tag (hlo_census.BUCKETS)
        if skv <= threshold:
            return _plain_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, scale)
        return _chunked_attention(
            q, k, v, q_pos, kv_pos, q_seg, kv_seg, scale, q_chunk=1024, kv_chunk=1024
        )


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array, kv_repeat: int):
    q = jnp.einsum("bsd,dkh->bskh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_repeat > 1:  # replicate kv heads so TP divides (DESIGN §5)
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    return q, k, v


def _group(q: jax.Array, n_kv_eff: int) -> jax.Array:
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv_eff, h // n_kv_eff, hd)


def attn_train(cfg: ModelConfig, p: dict, x, positions, segment_ids, kv_repeat: int = 1):
    q, k, v = _qkv(cfg, p, x, positions, kv_repeat)
    n_kv_eff = cfg.num_kv_heads * kv_repeat
    q = _group(q, n_kv_eff)
    q = hint(q, "dp", None, "heads", None, None)
    k = hint(k, "dp", None, "heads", None)
    o = _sdpa(cfg, q, k, v, positions, positions, segment_ids, segment_ids)
    b, s = x.shape[:2]
    o = o.reshape(b, s, cfg.num_heads, cfg.resolved_head_dim)
    return jnp.einsum("bskh,khd->bsd", o, p["wo"])


def attn_prefill(cfg: ModelConfig, p: dict, x, positions, segment_ids, kv_repeat: int = 1):
    q, k, v = _qkv(cfg, p, x, positions, kv_repeat)
    n_kv_eff = cfg.num_kv_heads * kv_repeat
    o = _sdpa(cfg, _group(q, n_kv_eff), k, v, positions, positions, segment_ids, segment_ids)
    b, s = x.shape[:2]
    o = o.reshape(b, s, cfg.num_heads, cfg.resolved_head_dim)
    y = jnp.einsum("bskh,khd->bsd", o, p["wo"])
    return y, {"k": k, "v": v}


def attn_decode(
    cfg: ModelConfig,
    p: dict,
    x,  # (B, 1, D)
    cache: dict,  # k/v: (B, S_cap, KV_eff, hd)
    pos: jax.Array,  # scalar int32: index of the new token
    kv_repeat: int = 1,
):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(cfg, p, x, positions, kv_repeat)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    k = hint(k, "dp", "sp", "heads", None)
    v = hint(v, "dp", "sp", "heads", None)
    s_cap = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(s_cap, dtype=jnp.int32), (b, s_cap))
    # mask out unwritten cache slots (> pos)
    kv_seg = jnp.where(kv_pos <= pos, 0, -1)
    q_seg = jnp.zeros((b, 1), jnp.int32)
    n_kv_eff = cfg.num_kv_heads * kv_repeat
    o = _plain_attention(
        _group(q, n_kv_eff), k, v, positions, kv_pos, q_seg, kv_seg,
        1.0 / math.sqrt(cfg.resolved_head_dim),
    )
    o = o.reshape(b, 1, cfg.num_heads, cfg.resolved_head_dim)
    y = jnp.einsum("bskh,khd->bsd", o, p["wo"])
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3)
# ---------------------------------------------------------------------------


def _mla_q(cfg: ModelConfig, p: dict, x, positions):
    m = cfg.mla
    cq = rms_norm_simple(x @ p["w_dq"], p["q_norm"])
    q = jnp.einsum("bsr,rkh->bskh", cq, p["w_uq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(cfg: ModelConfig, p: dict, x, positions):
    m = cfg.mla
    dkv = x @ p["w_dkv"]  # (B,S, kv_lora + rope)
    ckv = rms_norm_simple(dkv[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = dkv[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_train(cfg: ModelConfig, p: dict, x, positions, segment_ids, kv_repeat: int = 1):
    y, _ = _mla_forward(cfg, p, x, positions, segment_ids)
    return y


def mla_prefill(cfg: ModelConfig, p: dict, x, positions, segment_ids, kv_repeat: int = 1):
    return _mla_forward(cfg, p, x, positions, segment_ids)


def _mla_forward(cfg: ModelConfig, p: dict, x, positions, segment_ids):
    """Non-absorbed form (compute-optimal when Sq == Skv)."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, k_rope = _mla_kv_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rkh->bskh", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rkh->bskh", ckv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1,
    )
    q = hint(q, "dp", None, "heads", None)
    # heads act as "kv groups of 1": reuse grouped sdpa with K=H, G=1
    o = _sdpa(cfg, q[:, :, :, None, :], k, v, positions, positions, segment_ids, segment_ids)
    o = o[:, :, :, 0, :]
    y = jnp.einsum("bskh,khd->bsd", o, p["wo"])
    return y, {"ckv": ckv, "k_rope": k_rope}


def mla_decode(cfg: ModelConfig, p: dict, x, cache: dict, pos: jax.Array, kv_repeat: int = 1):
    """Absorbed decode: attend in the latent space, O(S·(rank+rope)) per head."""
    m = cfg.mla
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)  # (B,1,H,·)
    ckv_new, kr_new = _mla_kv_latent(cfg, p, x, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1
    )
    ckv = hint(ckv, "dp", "sp", None)
    # absorb W_uk into q: score_nope = (q_nope W_uk)ᵀ · ckv
    _scope = jax.named_scope("attention"); _scope.__enter__()
    q_lat = jnp.einsum("bqkh,rkh->bqkr", q_nope, p["w_uk"])  # (B,1,H,rank)
    s_lat = jnp.einsum("bqkr,bsr->bkqs", q_lat, ckv, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqkh,bsh->bkqs", q_rope, k_rope, preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (s_lat + s_rope) * scale
    s_cap = ckv.shape[1]
    kv_ok = jnp.arange(s_cap, dtype=jnp.int32) <= pos
    s = jnp.where(kv_ok[None, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bkqs,bsr->bqkr", prob.astype(ckv.dtype), ckv)
    o = jnp.einsum("bqkr,rkh->bqkh", o_lat, p["w_uv"])  # (B,1,H,v_dim)
    _scope.__exit__(None, None, None)
    y = jnp.einsum("bskh,khd->bsd", o, p["wo"])
    return y, {"ckv": ckv, "k_rope": k_rope}

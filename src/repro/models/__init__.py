from .model import Model
from .params import abstract_params, init_params, param_count

__all__ = ["Model", "abstract_params", "init_params", "param_count"]

"""Top-k routed Mixture-of-Experts with capacity-based, batch-local dispatch.

Dispatch/combine are formulated so that ALL bulk data movement is batched
``take_along_axis`` gathers whose leading batch dim stays sharded over DP —
GSPMD partitions them locally.  (A naive flat scatter-add over the global
token dim has data-dependent indices, and the partitioner replicates a
(tokens × d_model) buffer per MoE layer — a 28 GiB/device disaster observed
in the DeepSeek-V3 dry-run.)  The only scatter left is a small s32
slot-permutation map.  Routing/capacity are therefore *per sequence* (the
standard per-device-dispatch granularity, MaxText-style); tokens overflowing
an expert's per-sequence capacity are dropped (capacity_factor gives
head-room).

The (B, E, C, D) capacity buffer is EP-sharded over "model"; the reshard
between batch-sharded gathers and expert-sharded compute is the MoE
all-to-all, visible in the dry-run collective table.  Includes DeepSeek
shared experts and the Switch load-balance aux loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.hints import hint
from .layers import apply_ffn, ffn_defs
from .params import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = {
        "router": ParamDef((d, e), ("embed", None), jnp.float32),
        "w_gate": ParamDef((e, d, f), ("experts", "expert_embed", "expert_ffn"), dt, fan_in_dims=(1,)),
        "w_up": ParamDef((e, d, f), ("experts", "expert_embed", "expert_ffn"), dt, fan_in_dims=(1,)),
        "w_down": ParamDef((e, f, d), ("experts", "expert_ffn", "expert_embed"), dt, fan_in_dims=(1,)),
    }
    if m.n_shared_experts:
        p["shared"] = ffn_defs(cfg, d_ff=m.n_shared_experts * m.d_expert)
    return p


def capacity_per_seq(cfg: ModelConfig, seq_len: int) -> int:
    m = cfg.moe
    c = int(seq_len * m.experts_per_token * m.capacity_factor / m.n_experts)
    return max(4, -(-c // 4) * 4)


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B,S,D) → (y (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    k = m.experts_per_token
    e = m.n_experts
    n = s * k
    cap = capacity_per_seq(cfg, s)
    scope = jax.named_scope("moe")
    scope.__enter__()

    # -- routing (fp32) ------------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gate, idx = jax.lax.top_k(probs, k)  # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # -- load-balance aux (Switch) -------------------------------------------
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jax.nn.one_hot(idx, e, dtype=jnp.float32).mean(axis=(0, 1, 2))  # no scatter
    aux = e * jnp.sum(me * ce) * m.router_aux_weight

    # -- per-sequence sort + capacity ----------------------------------------
    flat_e = idx.reshape(b, n)  # (B,N)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :], (b, n)
    )
    order = jnp.argsort(flat_e, axis=-1)  # stable
    e_sorted = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=-1)

    counts = jax.nn.one_hot(flat_e, e, dtype=jnp.int32).sum(axis=1)  # (B,E)
    offsets = jnp.cumsum(counts, axis=-1) - counts  # exclusive prefix per row
    pos = jnp.arange(n, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        offsets, e_sorted, axis=-1
    )
    keep = pos < cap
    slot = jnp.where(keep, e_sorted * cap + pos, e * cap)  # sentinel = E*cap

    # small s32 slot→token map (the ONLY scatter; (B, E*cap+1))
    slot_to_tok = jnp.full((b, e * cap + 1), s, jnp.int32)  # sentinel token = S
    batch_ix = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], (b, n))
    slot_to_tok = slot_to_tok.at[batch_ix, slot].set(tok_sorted, mode="drop")
    token_for_slot = slot_to_tok[:, : e * cap]  # (B, E*cap)
    valid = token_for_slot < s

    # -- dispatch: batched gather ---------------------------------------------
    buf = jnp.take_along_axis(
        x, jnp.minimum(token_for_slot, s - 1)[..., None], axis=1
    )  # (B, E*cap, D)
    buf = jnp.where(valid[..., None], buf, 0).reshape(b, e, cap, d)
    buf = hint(buf, "dp", "tp", None, None)  # EP reshard (the MoE all-to-all)

    # -- expert FFN (batched over experts, MXU-shaped) ------------------------
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    y_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y_buf = hint(y_buf, "dp", "tp", None, None).reshape(b, e * cap, d)

    # -- combine: two batched gathers (sorted → original order) ---------------
    y_sorted = jnp.take_along_axis(y_buf, jnp.where(keep, slot, 0)[..., None], axis=1)
    y_sorted = jnp.where(keep[..., None], y_sorted, 0)  # (B,N,D)
    inv_order = jnp.argsort(order, axis=-1)
    y_tok = jnp.take_along_axis(y_sorted, inv_order[..., None], axis=1)  # (B,N,D)
    y = (y_tok.reshape(b, s, k, d).astype(jnp.float32) * gate[..., None]).sum(axis=2)

    if m.n_shared_experts:
        y = y + apply_ffn(cfg, p["shared"], x).astype(jnp.float32)
    return y.astype(x.dtype), aux

"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill use the chunked SSD algorithm (quadratic-within-chunk "dual"
attention form + linear inter-chunk state recurrence); decode uses the O(1)
per-token recurrence.  ``ssd_chunked`` is the jnp reference the Pallas
kernel (kernels/ssd_scan.py) is validated against; ``ssd_recurrent`` is the
naive oracle used only in tests.

Shapes: x (B,L,H,P) head-split inputs, dt (B,L,H), A (H,) negative decay,
B/C (B,L,G,N) with G groups broadcast over heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSDConfig
from ..dist.hints import hint
from .layers import rms_norm_simple
from .params import ParamDef

# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_recurrent(x, dt, A, B, C, h0=None):
    """Naive stepwise oracle.  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ ;
    y_t = C_t · h_t.   Returns (y, h_final)."""
    b, l, h, p = x.shape
    g = B.shape[2]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)  # (B,L,H,N)
    Ch = jnp.repeat(C, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, B.shape[-1]), jnp.float32)

    def step(hprev, t):
        decay = jnp.exp(dt[:, t] * A)[:, :, None, None]  # (B,H,1,1)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, t], x[:, t].astype(jnp.float32), Bh[:, t].astype(jnp.float32))
        hnew = decay * hprev + upd
        y = jnp.einsum("bhpn,bhn->bhp", hnew, Ch[:, t].astype(jnp.float32))
        return hnew, y

    hfin, ys = jax.lax.scan(step, h0, jnp.arange(l))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hfin


def _segsum(z):
    """Stable 'segment sum': out[..., i, j] = sum_{j < k <= i} z[..., k],
    lower-triangular (i >= j), -inf above the diagonal."""
    l = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, h0=None, chunk: int = 64):
    """Chunked SSD scan — one sequential ``lax.scan`` over chunks.

    Per chunk: the dual (attention-like) quadratic-in-Q form computes
    intra-chunk interactions, the carried state contributes the prefix, and
    the state advances with one decay + rank-Q update.  Peak memory is
    O(B·H·Q²) for one chunk (not O(L·Q) like the fully-vectorized form),
    which is what lets Jamba-scale prefill_32k fit HBM.  Returns (y, h_final).
    """
    b, l, h, p = x.shape
    g = B.shape[2]
    n = B.shape[-1]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bf = (
        jnp.repeat(B, rep, axis=2).astype(jnp.float32)
        .reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    )
    Cf = (
        jnp.repeat(C, rep, axis=2).astype(jnp.float32)
        .reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    )
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    @jax.checkpoint
    def chunk_body(hprev, inp):
        x_c, dt_c, B_c, C_c = inp  # (B,Q,H,·)
        dA = dt_c * A  # (B,Q,H)
        dA_cs = jnp.cumsum(dA, axis=1)
        # intra-chunk dual form
        L = jnp.exp(_segsum(dA.transpose(0, 2, 1)))  # (B,H,Q,Q)
        scores = jnp.einsum("bqhn,bkhn->bhqk", C_c, B_c) * L
        y_diag = jnp.einsum("bhqk,bkh,bkhp->bqhp", scores, dt_c, x_c)
        # contribution of the carried prefix state
        in_decay = jnp.exp(dA_cs)  # (B,Q,H)
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", C_c, hprev, in_decay)
        # state update
        decay_to_end = jnp.exp(dA_cs[:, -1:, :] - dA_cs)  # (B,Q,H)
        s_c = jnp.einsum("bqhn,bqh,bqh,bqhp->bhpn", B_c, dt_c, decay_to_end, x_c)
        hnew = hprev * jnp.exp(dA_cs[:, -1, :])[:, :, None, None] + s_c
        return hnew, y_diag + y_off

    with jax.named_scope("ssd"):  # census bucket tag
        hfin, ys = jax.lax.scan(chunk_body, h0, (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    return y.astype(x.dtype), hfin


def ssd_decode_step(x, dt, A, B, C, h):
    """One-token recurrence.  x (B,H,P), dt (B,H), B/C (B,G,N), h (B,H,P,N)."""
    g = B.shape[1]
    rep = x.shape[1] // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A)[:, :, None, None]
    hnew = decay * h + jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), Bh)
    y = jnp.einsum("bhpn,bhn->bhp", hnew, Ch)
    return y.astype(x.dtype), hnew


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------


def ssd_defs(cfg: ModelConfig) -> dict:
    s = cfg.ssd
    assert s is not None
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    zxbcdt = 2 * di + 2 * s.n_groups * s.d_state + nh
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": ParamDef((d, zxbcdt), ("embed", "d_inner"), dt),
        "conv_w": ParamDef((s.d_conv, conv_dim), (None, "conv_dim"), dt),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), dt, "zeros"),
        "A_log": ParamDef((nh,), ("ssd_heads",), jnp.float32, "zeros"),
        "dt_bias": ParamDef((nh,), ("ssd_heads",), jnp.float32, "zeros"),
        "D": ParamDef((nh,), ("ssd_heads",), jnp.float32, "ones"),
        "norm": ParamDef((di,), ("d_inner",), jnp.float32, "ones"),
        "out_proj": ParamDef((di, d), ("d_inner", "embed"), dt),
    }


def _split_zxbcdt(cfg: ModelConfig, zxbcdt):
    s = cfg.ssd
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * gn]
    dt_raw = zxbcdt[..., 2 * di + 2 * gn :]
    return z, xBC, dt_raw


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over time.  xBC (B,L,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled K-tap FIR (K=4): cheap, fusion-friendly, Pallas-free
    y = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(k))
    return y + b


def _conv_step(x_t, conv_state, w, b):
    """x_t (B,C); conv_state (B,K-1,C) holding the previous inputs."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full, w) + b
    return y, full[:, 1:, :]


def ssd_block_train(cfg: ModelConfig, p: dict, x, positions=None, segment_ids=None, kv_repeat: int = 1):
    y, _ = _ssd_block_forward(cfg, p, x)
    return y


def ssd_block_prefill(cfg: ModelConfig, p: dict, x, positions=None, segment_ids=None, kv_repeat: int = 1):
    return _ssd_block_forward(cfg, p, x)


def _ssd_block_forward(cfg: ModelConfig, p: dict, x):
    s = cfg.ssd
    b, l, _ = x.shape
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state

    zxbcdt = x @ p["in_proj"]
    z, xBC_raw, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    conv_state = _last_conv_window(xBC_raw, s.d_conv)  # for decode continuation
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :di].reshape(b, l, nh, s.head_dim)
    xs = hint(xs, "dp", None, "heads", None)
    Bm = xBC[..., di : di + gn].reshape(b, l, s.n_groups, s.d_state)
    Cm = xBC[..., di + gn :].reshape(b, l, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    A = -jnp.exp(p["A_log"])

    chunk = min(s.chunk, l) if l % min(s.chunk, l) == 0 else _best_chunk(l, s.chunk)
    y, h_fin = ssd_chunked(xs, dtv, A, Bm, Cm, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(b, l, di)
    y = rms_norm_simple(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    return out, {"ssm": h_fin.astype(jnp.float32), "conv": conv_state}


def _last_conv_window(xBC, d_conv):
    b, l, c = xBC.shape
    pad = jnp.pad(xBC, ((0, 0), (max(0, d_conv - 1 - l), 0), (0, 0)))
    return pad[:, -(d_conv - 1) :, :]


def _best_chunk(l, pref):
    for c in (pref, 128, 64, 32, 16, 8, 4, 2, 1):
        if c <= l and l % c == 0:
            return c
    return 1


def ssd_block_decode(cfg: ModelConfig, p: dict, x, cache: dict, pos=None, kv_repeat: int = 1):
    """x (B,1,D); cache {"ssm": (B,H,P,N) fp32, "conv": (B,K-1,C)}."""
    s = cfg.ssd
    b = x.shape[0]
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state

    zxbcdt = x[:, 0, :] @ p["in_proj"]  # (B, zxbcdt)
    z, xBC, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    xBC, conv_state = _conv_step(xBC, cache["conv"], p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :di].reshape(b, nh, s.head_dim)
    Bm = xBC[..., di : di + gn].reshape(b, s.n_groups, s.d_state)
    Cm = xBC[..., di + gn :].reshape(b, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])

    y, h_new = ssd_decode_step(xs, dtv, A, Bm, Cm, cache["ssm"])
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, di)
    y = rms_norm_simple(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"ssm": h_new, "conv": conv_state}

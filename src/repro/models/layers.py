"""Shared neural layers: norms, FFN, RoPE, embeddings.

All compute keeps bf16 activations with fp32 reductions (norms, softmax,
loss).  Parameters are declared as ParamDef trees; apply functions are pure.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import ParamDef

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_defs(cfg: ModelConfig) -> dict:
    if cfg.norm == "nonparam_ln":  # OLMo: no learnable affine
        return {}
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef((cfg.d_model,), (None,), jnp.float32, "ones"),
            "bias": ParamDef((cfg.d_model,), (None,), jnp.float32, "zeros"),
        }
    return {"scale": ParamDef((cfg.d_model,), (None,), jnp.float32, "ones")}


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    return y.astype(x.dtype)


def rms_norm_simple(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------


def ffn_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, _dt(cfg)
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("embed", "ffn"), dt),
            "w_up": ParamDef((d, f), ("embed", "ffn"), dt),
            "w_down": ParamDef((f, d), ("ffn", "embed"), dt),
        }
    return {
        "w_up": ParamDef((d, f), ("embed", "ffn"), dt),
        "w_down": ParamDef((f, d), ("ffn", "embed"), dt),
    }


def apply_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> dict:
    dt = _dt(cfg)
    return {
        "tok": ParamDef(
            (cfg.n_codebooks, cfg.padded_vocab, cfg.d_model),
            (None, "vocab_in", "embed"),
            dt,
            "embed_normal",
        )
    }


def apply_embed(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) int32 or (B, S, n_codebooks) for multi-codebook audio."""
    if cfg.n_codebooks == 1:
        if tokens.ndim == 3:
            tokens = tokens[..., 0]
        return p["tok"][0][tokens]
    # MusicGen-style: sum of per-codebook embeddings
    parts = [p["tok"][q][tokens[..., q]] for q in range(cfg.n_codebooks)]
    return sum(parts)


def head_defs(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {
        "w": ParamDef(
            (cfg.d_model, cfg.n_codebooks * cfg.padded_vocab),
            ("embed", "vocab"),
            _dt(cfg),
        )
    }


def apply_head(cfg: ModelConfig, head_p: dict, embed_p: dict, x: jax.Array) -> jax.Array:
    """Returns logits (B, S, n_codebooks*padded_vocab).  Padded columns must
    be masked by the caller (``mask_padded_vocab``)."""
    if cfg.tie_embeddings:
        w = embed_p["tok"].reshape(cfg.n_codebooks * cfg.padded_vocab, cfg.d_model).T
        return x @ w
    return x @ head_p["w"]


def mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """logits (..., padded_vocab): -inf the padding columns so they never
    win the softmax/argmax and contribute nothing to the logsumexp."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    ok = jnp.arange(logits.shape[-1]) < cfg.vocab_size
    return jnp.where(ok, logits, -2.0**30)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean CE over masked positions.  logits (..., V), labels int32 (may be
    negative at masked positions), mask float (0/1)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

"""Block assembly: (mixer + FFN/MoE) layers, grouped into scanned segments.

``cfg.segments()`` splits the layer stack into repetitions of identical
super-blocks (e.g. Jamba's [attn, ssd×7] with alternating MoE).  Parameters
of a segment are *stacked* (leading "layers" dim) and the segment is applied
with ``jax.lax.scan`` — HLO stays O(super-block), compiles fast even for
80-layer models on 512 devices, and remat wraps each scan body iteration.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention, moe as moe_mod, ssm
from .layers import apply_ffn, apply_norm, ffn_defs, norm_defs
from .params import ParamDef, tree_map_defs

MIXER_DEFS = {"attn": attention.attn_defs, "mla": attention.mla_defs, "ssd": ssm.ssd_defs}
MIXER_TRAIN = {"attn": attention.attn_train, "mla": attention.mla_train, "ssd": ssm.ssd_block_train}
MIXER_PREFILL = {
    "attn": attention.attn_prefill,
    "mla": attention.mla_prefill,
    "ssd": ssm.ssd_block_prefill,
}
MIXER_DECODE = {
    "attn": attention.attn_decode,
    "mla": attention.mla_decode,
    "ssd": ssm.ssd_block_decode,
}


# ---------------------------------------------------------------------------
# per-layer defs / apply
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, kind: str, is_moe: bool) -> dict:
    d: dict[str, Any] = {"norm1": norm_defs(cfg), "mixer": MIXER_DEFS[kind](cfg)}
    has_ffn = is_moe or cfg.d_ff > 0
    if has_ffn:
        d["norm2"] = norm_defs(cfg)
        d["ffn"] = moe_mod.moe_defs(cfg) if is_moe else ffn_defs(cfg)
    return d


def block_apply_train(cfg, kind, is_moe, p, x, positions, segment_ids, kv_repeat):
    h = apply_norm(cfg, p["norm1"], x)
    y = MIXER_TRAIN[kind](cfg, p["mixer"], h, positions, segment_ids, kv_repeat)
    x = x + y.astype(x.dtype)
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = apply_norm(cfg, p["norm2"], x)
        if is_moe:
            y, aux = moe_mod.apply_moe(cfg, p["ffn"], h)
        else:
            y = apply_ffn(cfg, p["ffn"], h)
        x = x + y.astype(x.dtype)
    return x, aux


def block_apply_prefill(cfg, kind, is_moe, p, x, positions, segment_ids, kv_repeat):
    h = apply_norm(cfg, p["norm1"], x)
    y, cache = MIXER_PREFILL[kind](cfg, p["mixer"], h, positions, segment_ids, kv_repeat)
    x = x + y.astype(x.dtype)
    if "ffn" in p:
        h = apply_norm(cfg, p["norm2"], x)
        if is_moe:
            y, _ = moe_mod.apply_moe(cfg, p["ffn"], h)
        else:
            y = apply_ffn(cfg, p["ffn"], h)
        x = x + y.astype(x.dtype)
    return x, cache


def block_apply_decode(cfg, kind, is_moe, p, x, cache, pos, kv_repeat):
    h = apply_norm(cfg, p["norm1"], x)
    y, new_cache = MIXER_DECODE[kind](cfg, p["mixer"], h, cache, pos, kv_repeat)
    x = x + y.astype(x.dtype)
    if "ffn" in p:
        h = apply_norm(cfg, p["norm2"], x)
        if is_moe:
            y, _ = moe_mod.apply_moe(cfg, p["ffn"], h)
        else:
            y = apply_ffn(cfg, p["ffn"], h)
        x = x + y.astype(x.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------


def stack_defs(tree: Any, n: int) -> Any:
    """Prepend a stacked "layers" dim of size n to every ParamDef."""
    return tree_map_defs(
        lambda d: ParamDef(
            (n, *d.shape),
            ("layers", *d.axes),
            d.dtype,
            d.init,
            tuple(i + 1 for i in d.fan_in_dims) if d.fan_in_dims else (),
        ),
        tree,
    )


def segment_defs(cfg: ModelConfig) -> list[dict]:
    segs = []
    for plan, n_repeat in cfg.segments():
        blocks = [block_defs(cfg, kind, is_moe) for kind, is_moe in plan]
        segs.append({"blocks": [stack_defs(b, n_repeat) for b in blocks]})
    return segs


def _maybe_remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "none":
        return jax.checkpoint(fn)  # full remat: nothing saved inside a layer
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def segment_train(cfg, seg_plan, seg_params, x, positions, segment_ids, kv_repeat):
    """Apply (super-block × n_repeat) via scan; returns (x, summed aux)."""

    def body(carry, layer_params):
        xc, aux = carry

        def inner(xc, layer_params):
            aux_i = jnp.zeros((), jnp.float32)
            for i, (kind, is_moe) in enumerate(seg_plan):
                xc, a = block_apply_train(
                    cfg, kind, is_moe, layer_params[i], xc, positions, segment_ids, kv_repeat
                )
                aux_i = aux_i + a
            return xc, aux_i

        xc, aux_i = _maybe_remat(cfg, inner)(xc, layer_params)
        return (xc, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), seg_params["blocks"])
    return x, aux


def segment_prefill(cfg, seg_plan, seg_params, x, positions, segment_ids, kv_repeat):
    def body(xc, layer_params):
        caches = []
        for i, (kind, is_moe) in enumerate(seg_plan):
            xc, cache = block_apply_prefill(
                cfg, kind, is_moe, layer_params[i], xc, positions, segment_ids, kv_repeat
            )
            caches.append(cache)
        return xc, caches

    x, caches = jax.lax.scan(body, x, seg_params["blocks"])
    return x, {"blocks": caches}


def segment_decode(cfg, seg_plan, seg_params, seg_cache, x, pos, kv_repeat):
    def body(xc, inp):
        layer_params, layer_cache = inp
        new_caches = []
        for i, (kind, is_moe) in enumerate(seg_plan):
            xc, nc = block_apply_decode(
                cfg, kind, is_moe, layer_params[i], xc, layer_cache[i], pos, kv_repeat
            )
            new_caches.append(nc)
        return xc, new_caches

    x, new_cache = jax.lax.scan(body, x, (seg_params["blocks"], seg_cache["blocks"]))
    return x, {"blocks": new_cache}

"""Model: the end-to-end LM API used by trainer, server, and dry-run.

- ``train_loss(params, batch)``      → (loss, metrics)
- ``prefill(params, batch)``         → (last-position logits, cache)
- ``decode_step(params, cache, tokens, pos)`` → (logits, new cache)
- ``batch_spec(shape)`` / ``cache_spec(...)`` → ShapeDtypeStructs for AOT
  lowering (full-size architectures are never materialized on this host).

Supports every assigned family: dense/GQA, MLA+MoE (DeepSeek, incl. the MTP
aux module), SSD (Mamba2), hybrid (Jamba), multi-codebook audio (MusicGen)
and vision-prefix VLM (InternVL2, frontend stubbed per assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..dist.hints import hint
from ..dist.sharding import ParallelPlan, NULL_PLAN
from . import transformer as tf
from .layers import (
    apply_embed,
    apply_head,
    apply_norm,
    cross_entropy,
    embed_defs,
    head_defs,
    mask_padded_vocab,
    norm_defs,
)
from .params import ParamDef, abstract_params, init_params, param_count

MTP_WEIGHT = 0.3


class Model:
    def __init__(self, cfg: ModelConfig, plan: ParallelPlan = NULL_PLAN):
        self.cfg = cfg
        self.plan = plan
        self.kv_repeat = plan.kv_repeat

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def param_defs(self) -> dict:
        cfg = self.cfg
        d: dict[str, Any] = {
            "embed": embed_defs(cfg),
            "segments": tf.segment_defs(cfg),
            "final_norm": norm_defs(cfg),
            "head": head_defs(cfg),
        }
        if cfg.vis_prefix_len:
            # learnable projection applied to the (stubbed) frontend output
            d["vis_proj"] = {
                "w": ParamDef((cfg.d_model, cfg.d_model), ("embed", None), _dt(cfg)),
            }
        if cfg.mtp:
            kind = cfg.block_kinds()[0]
            d["mtp"] = {
                "proj": ParamDef((2 * cfg.d_model, cfg.d_model), (None, "embed"), _dt(cfg)),
                "norm_h": norm_defs(cfg),
                "norm_e": norm_defs(cfg),
                "block": tf.block_defs(cfg, kind, False),
                "final_norm": norm_defs(cfg),
            }
        return d

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    def abstract_params(self) -> dict:
        return abstract_params(self.param_defs())

    def param_count(self) -> int:
        return param_count(self.param_defs())

    # ------------------------------------------------------------------
    # embedding & head helpers
    # ------------------------------------------------------------------
    def _embed_inputs(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        x = apply_embed(cfg, params["embed"], batch["tokens"])
        if cfg.vis_prefix_len:
            vis = batch["vis_embed"].astype(x.dtype) @ params["vis_proj"]["w"]
            x = jax.lax.dynamic_update_slice_in_dim(x, vis, 0, axis=1)
        return hint(x, "dp", None, None)

    def _lm_loss(self, params: dict, h: jax.Array, batch: dict) -> jax.Array:
        cfg = self.cfg
        logits = apply_head(cfg, params["head"], params["embed"], h)
        labels = batch["labels"]
        if cfg.n_codebooks > 1:
            b, s = logits.shape[:2]
            logits = logits.reshape(b, s, cfg.n_codebooks, cfg.padded_vocab)
            logits = mask_padded_vocab(cfg, logits)
            mask = (labels >= 0).astype(jnp.float32)
            return cross_entropy(logits, labels, mask)
        logits = mask_padded_vocab(cfg, logits)
        if labels.ndim == 3:
            labels = labels[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return cross_entropy(logits, labels, mask)

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------
    def train_loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
            )
        segment_ids = batch.get("segment_ids")
        if segment_ids is None:
            segment_ids = jnp.zeros(x.shape[:2], jnp.int32)

        aux_total = jnp.zeros((), jnp.float32)
        for seg_params, (seg_plan, _) in zip(params["segments"], cfg.segments()):
            x, aux = tf.segment_train(
                cfg, seg_plan, seg_params, x, positions, segment_ids, self.kv_repeat
            )
            aux_total = aux_total + aux

        h = apply_norm(cfg, params["final_norm"], x)
        loss_lm = self._lm_loss(params, h, batch)
        loss = loss_lm + aux_total
        metrics = {"loss_lm": loss_lm, "aux": aux_total}

        if cfg.mtp:
            loss_mtp = self._mtp_loss(params, x, batch, positions, segment_ids)
            loss = loss + MTP_WEIGHT * loss_mtp
            metrics["loss_mtp"] = loss_mtp
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, batch, positions, segment_ids):
        """DeepSeek-V3 multi-token prediction (1 extra depth): at position t,
        combine backbone h_t with the embedding of token t+1 and predict
        token t+2 through one extra block sharing embed/head."""
        cfg = self.cfg
        mtp = params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        if tokens.ndim == 3:
            tokens = tokens[..., 0]
        if labels.ndim == 3:
            labels = labels[..., 0]
        tok_next = jnp.roll(tokens, -1, axis=1)
        emb_next = apply_embed(cfg, params["embed"], tok_next)
        z = jnp.concatenate(
            [apply_norm(cfg, mtp["norm_h"], h), apply_norm(cfg, mtp["norm_e"], emb_next)],
            axis=-1,
        )
        z = z @ mtp["proj"]
        kind = cfg.block_kinds()[0]
        z, _ = tf.block_apply_train(
            cfg, kind, False, mtp["block"], z, positions, segment_ids, self.kv_repeat
        )
        z = apply_norm(cfg, mtp["final_norm"], z)
        logits = mask_padded_vocab(cfg, apply_head(cfg, params["head"], params["embed"], z))
        labels_p1 = jnp.roll(labels, -1, axis=1)
        mask = (labels_p1 >= 0).astype(jnp.float32)
        # the final 2 positions have no t+2 target
        mask = mask.at[:, -2:].set(0.0)
        return cross_entropy(logits, labels_p1, mask)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def prefill(self, params: dict, batch: dict) -> tuple[jax.Array, list]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
            )
        segment_ids = jnp.zeros(x.shape[:2], jnp.int32)
        caches = []
        for seg_params, (seg_plan, _) in zip(params["segments"], cfg.segments()):
            x, cache = tf.segment_prefill(
                cfg, seg_plan, seg_params, x, positions, segment_ids, self.kv_repeat
            )
            caches.append(cache)
        h = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
        logits = apply_head(cfg, params["head"], params["embed"], h)[:, 0]
        return self._shape_logits(logits), caches

    def decode_step(
        self, params: dict, caches: list, tokens: jax.Array, pos: jax.Array
    ) -> tuple[jax.Array, list]:
        """tokens (B,1) or (B,1,ncb); pos: scalar int32 index being written."""
        cfg = self.cfg
        x = apply_embed(cfg, params["embed"], tokens)
        x = hint(x, "dp", None, None)
        new_caches = []
        for seg_params, seg_cache, (seg_plan, _) in zip(
            params["segments"], caches, cfg.segments()
        ):
            x, nc = tf.segment_decode(
                cfg, seg_plan, seg_params, seg_cache, x, pos, self.kv_repeat
            )
            new_caches.append(nc)
        h = apply_norm(cfg, params["final_norm"], x)
        logits = apply_head(cfg, params["head"], params["embed"], h)[:, 0]
        return self._shape_logits(logits), new_caches

    def _shape_logits(self, logits: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            logits = logits.reshape(logits.shape[0], cfg.n_codebooks, cfg.padded_vocab)
        return mask_padded_vocab(cfg, logits)

    # ------------------------------------------------------------------
    # AOT specs (dry-run: ShapeDtypeStruct only, no allocation)
    # ------------------------------------------------------------------
    def batch_spec(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
        spec: dict[str, Any] = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
            spec["positions"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            spec["segment_ids"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.vis_prefix_len and shape.kind != "decode":
            spec["vis_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.vis_prefix_len, cfg.d_model), _dt(cfg)
            )
        return spec

    def cache_spec(self, batch: int, seq_cap: int) -> list:
        """Mirror of the prefill cache structure with given capacity."""
        cfg = self.cfg
        out = []
        for seg_plan, n_repeat in cfg.segments():
            blocks = []
            for kind, _ in seg_plan:
                blocks.append(self._mixer_cache_spec(kind, n_repeat, batch, seq_cap))
            out.append({"blocks": blocks})
        return out

    def _mixer_cache_spec(self, kind: str, n: int, b: int, s_cap: int) -> dict:
        cfg = self.cfg
        dt = _dt(cfg)
        if kind == "attn":
            kv_eff = cfg.num_kv_heads * self.kv_repeat
            hd = cfg.resolved_head_dim
            return {
                "k": jax.ShapeDtypeStruct((n, b, s_cap, kv_eff, hd), dt),
                "v": jax.ShapeDtypeStruct((n, b, s_cap, kv_eff, hd), dt),
            }
        if kind == "mla":
            m = cfg.mla
            return {
                "ckv": jax.ShapeDtypeStruct((n, b, s_cap, m.kv_lora_rank), dt),
                "k_rope": jax.ShapeDtypeStruct((n, b, s_cap, m.qk_rope_head_dim), dt),
            }
        s = cfg.ssd
        di = s.d_inner(cfg.d_model)
        conv_dim = di + 2 * s.n_groups * s.d_state
        return {
            "ssm": jax.ShapeDtypeStruct(
                (n, b, s.n_heads(cfg.d_model), s.head_dim, s.d_state), jnp.float32
            ),
            "conv": jax.ShapeDtypeStruct((n, b, s.d_conv - 1, conv_dim), dt),
        }


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

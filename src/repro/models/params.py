"""Parameter declaration: shapes + logical sharding axes + initializers.

Model code declares parameters as ``ParamDef`` pytrees.  From one tree we
derive (a) materialized params (small/smoke models), (b) ShapeDtypeStructs
for AOT lowering (full-size models are **never** allocated on this host),
and (c) ``NamedSharding``s by mapping *logical* axis names ("embed", "heads",
"ffn", "vocab", "experts", ...) to mesh axes through per-arch rules
(``dist/sharding.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim (None = never sharded)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | embed_normal
    fan_in_dims: tuple[int, ...] = ()  # dims forming fan-in for scaled init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaves(tree: Pytree) -> list[ParamDef]:
    return [x for x in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, ParamDef))]


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: Pytree) -> Pytree:
    return jax.tree.map(fn, tree, is_leaf=lambda x: isinstance(x, ParamDef))


def param_count(tree: Pytree) -> int:
    return sum(math.prod(d.shape) for d in _leaves(tree))


def abstract_params(tree: Pytree) -> Pytree:
    """ShapeDtypeStruct tree — for .lower() without allocation."""
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def init_params(tree: Pytree, key: jax.Array) -> Pytree:
    """Materialize parameters (used for smoke/real training of small models)."""
    defs = _leaves(tree)
    keys = jax.random.split(key, len(defs))
    it = iter(range(len(defs)))

    def one(d: ParamDef) -> jax.Array:
        i = next(it)
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        fan_in = (
            math.prod(d.shape[dim] for dim in d.fan_in_dims)
            if d.fan_in_dims
            else (d.shape[-2] if len(d.shape) >= 2 else d.shape[-1])
        )
        scale = 1.0 if d.init == "embed_normal" else 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(keys[i], d.shape, jnp.float32) * scale).astype(d.dtype)

    return tree_map_defs(one, tree)


def logical_specs(tree: Pytree) -> Pytree:
    """Tree of logical-axis tuples (same structure as params)."""
    return tree_map_defs(lambda d: d.axes, tree)


def resolve_pspec(
    axes: tuple[str | None, ...], rules: dict[str, Any]
) -> jax.sharding.PartitionSpec:
    """Map logical axes to mesh axes.  A rule value may be a mesh-axis name,
    a tuple of names, or None.  A mesh axis may be used at most once per
    param; later dims lose (stay replicated) if an axis is already taken."""
    used: set[str] = set()
    out: list[Any] = []
    for ax in axes:
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            out.append(None)
            continue
        axs = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        free = tuple(a for a in axs if a not in used)
        if not free:
            out.append(None)
            continue
        used.update(free)
        out.append(free if len(free) > 1 else free[0])
    while out and out[-1] is None:
        out.pop()
    return jax.sharding.PartitionSpec(*out)


def param_pspecs(tree: Pytree, rules: dict[str, Any]) -> Pytree:
    return tree_map_defs(lambda d: resolve_pspec(d.axes, rules), tree)


def param_shardings(tree: Pytree, mesh: jax.sharding.Mesh, rules: dict[str, Any]) -> Pytree:
    return tree_map_defs(
        lambda d: jax.sharding.NamedSharding(mesh, resolve_pspec(d.axes, rules)), tree
    )


def shard_info(tree: Pytree, rules: dict[str, Any], mesh_shape: dict[str, int]) -> dict:
    """Bytes-per-device accounting used by capacity planning & EXPERIMENTS.md."""
    total = 0
    per_device = 0
    for d in _leaves(tree):
        n = math.prod(d.shape)
        bytes_ = n * np.dtype(d.dtype).itemsize
        spec = resolve_pspec(d.axes, rules)
        div = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in entry if isinstance(entry, tuple) else (entry,):
                div *= mesh_shape.get(ax, 1)
        total += bytes_
        per_device += bytes_ // div
    return {"total_bytes": total, "per_device_bytes": per_device}
